//! TCP cluster transport for the shard layer: `hte-pinn worker` serve
//! loop, the rank-0 [`TcpClusterBackend`], and the framed wire protocol
//! between them (DESIGN.md §10).
//!
//! Design constraints, in order:
//!
//! 1. **Bitwise determinism.**  A worker runs the *same*
//!    [`shard_loss_grad`](crate::nn::shard_loss_grad) kernel on the
//!    *same* [`ShardPlan`] shards a local thread would, and returns
//!    per-shard results tagged by shard index; rank 0 merges them with
//!    the same shard-index-ordered reduction the in-process backend
//!    feeds.  Probe/batch randomness never leaves rank 0 — workers
//!    receive the sampled batch, so RNG streams (and checkpoint-resume
//!    replay) are executor-independent by construction.  The guarantee
//!    holds across processes on the same ISA; heterogeneous ISAs differ
//!    in libm last bits (DESIGN.md §9).
//! 2. **No hangs.**  Every frame is length-prefixed; a dead peer is an
//!    EOF or reset, surfaced as a clear `anyhow` diagnostic naming the
//!    worker, and reads carry a generous timeout
//!    (`HTE_WORKER_TIMEOUT_SECS`, default 600) so a wedged-but-open
//!    socket cannot block training forever.
//! 3. **No serde dependency.**  The container format is hand-rolled
//!    little-endian framing (`[magic u32][tag u8][len u64][payload]`)
//!    with f32/f64 values shipped as raw bit patterns — exactly the
//!    bits, nothing reinterpreted.
//!
//! Protocol (one coordinator per worker at a time):
//!
//! ```text
//! coordinator                         worker
//!   HELLO {version, family, method,
//!          lambda_g, d, n_params}  ->
//!                                  <- HELLO_ACK {op, chunk_points, threads}
//!                                     (or ERROR {message})
//!   per step:
//!   STEP {step, shard_lo..hi, n, v,
//!         chunk_points, base, params,
//!         xs-slice, probes, coeff} ->
//!                                  <- RESULT {step, [index, loss, grad]*}
//!                                     (or ERROR {message})
//!   (connection drop = goodbye)
//! ```

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::ops::Range;
use std::path::Path;
use std::process::{Child, ChildStdout, Command, Stdio};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::{problem_for, TrainConfig};
use crate::nn::{residual_op_for, Mlp, NativeBatch, ResidualOp, CHUNK_POINTS};
use crate::pde::PdeProblem;
use crate::rng::Xoshiro256pp;

use super::shard::{prepare_results, ShardBackend, ShardJob, ShardPlan, ShardResult};

/// Bumped whenever a frame layout changes; a version mismatch is a hard
/// handshake error (shipping shards to a differently-planned binary
/// would silently break the bitwise guarantee).
pub const PROTOCOL_VERSION: u32 = 1;

const FRAME_MAGIC: u32 = 0x4854_4550; // "HTEP"
/// Hard cap against garbage peers / corrupted length words.
const MAX_FRAME: u64 = 1 << 33;

const TAG_HELLO: u8 = 1;
const TAG_HELLO_ACK: u8 = 2;
const TAG_STEP: u8 = 3;
const TAG_RESULT: u8 = 4;
const TAG_ERROR: u8 = 5;

fn worker_timeout() -> Duration {
    let secs = std::env::var("HTE_WORKER_TIMEOUT_SECS")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(600);
    Duration::from_secs(secs.max(1))
}

// ---------------------------------------------------------------------------
// Wire encoding (hand-rolled little-endian, bit-exact floats)
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn f32(&mut self, x: f32) {
        self.u32(x.to_bits());
    }
    fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        self.buf.reserve(xs.len() * 4);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated frame payload: wanted {n} bytes at offset {}, frame has {}",
                self.pos,
                self.buf.len()
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn str(&mut self) -> Result<&'a str> {
        let n = self.u32()? as usize;
        std::str::from_utf8(self.bytes(n)?).context("non-UTF8 string in frame")
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.f32s_into(&mut out)?;
        Ok(out)
    }
    /// Decode into a caller-owned buffer (the rank-0 gather reuses each
    /// shard's gradient Vec across steps — no steady-state allocation).
    fn f32s_into(&mut self, out: &mut Vec<f32>) -> Result<()> {
        let n = self.u64()? as usize;
        let raw = self.bytes(n.checked_mul(4).context("absurd f32 array length")?)?;
        out.clear();
        out.reserve(n);
        out.extend(raw.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])));
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

fn write_frame(stream: &mut TcpStream, tag: u8, payload: &[u8]) -> std::io::Result<()> {
    let mut head = [0u8; 13];
    head[..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    head[4] = tag;
    head[5..].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    stream.write_all(&head)?;
    stream.write_all(payload)?;
    stream.flush()
}

/// Read one frame; `Ok(None)` on a clean EOF *between* frames (the peer
/// said goodbye by closing), an error on anything torn mid-frame.
fn read_frame_or_eof(stream: &mut TcpStream) -> Result<Option<(u8, Vec<u8>)>> {
    let mut head = [0u8; 13];
    let mut got = 0usize;
    while got < head.len() {
        let k = stream.read(&mut head[got..]).context("reading frame header")?;
        if k == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("peer closed the connection mid-frame header");
        }
        got += k;
    }
    let magic = u32::from_le_bytes([head[0], head[1], head[2], head[3]]);
    if magic != FRAME_MAGIC {
        bail!("bad frame magic {magic:#010x} — peer is not an hte-pinn shard endpoint");
    }
    let tag = head[4];
    let len = u64::from_le_bytes([
        head[5], head[6], head[7], head[8], head[9], head[10], head[11], head[12],
    ]);
    if len > MAX_FRAME {
        bail!("absurd frame length {len} (corrupted stream?)");
    }
    // Grow the payload buffer only as fast as bytes actually arrive: a
    // garbage peer sending a huge length word cannot make us pre-allocate
    // gigabytes — it would have to stream the bytes (and the read
    // timeout bounds how long it may take).
    let len = len as usize;
    const READ_CHUNK: usize = 1 << 20;
    let mut payload: Vec<u8> = Vec::with_capacity(len.min(READ_CHUNK));
    while payload.len() < len {
        let take = (len - payload.len()).min(READ_CHUNK);
        let start = payload.len();
        payload.resize(start + take, 0);
        stream
            .read_exact(&mut payload[start..])
            .context("peer closed the connection mid-frame")?;
    }
    Ok(Some((tag, payload)))
}

/// Read one frame, treating EOF as an error (rank 0 waiting on results).
fn read_frame(stream: &mut TcpStream) -> Result<(u8, Vec<u8>)> {
    read_frame_or_eof(stream)?
        .context("peer closed the connection (worker process died or was killed?)")
}

fn send_error(stream: &mut TcpStream, msg: &str) -> std::io::Result<()> {
    let mut e = Enc::default();
    e.str(msg);
    write_frame(stream, TAG_ERROR, &e.buf)
}

// ---------------------------------------------------------------------------
// Job spec (what a worker needs to rebuild problem/op/net)
// ---------------------------------------------------------------------------

/// Everything a worker needs to reconstruct the residual job locally:
/// the problem family, the method string (one shared
/// `residual_op_for` mapping on both ends), the gPINN weight, and the
/// dimensions to validate against.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    pub family: String,
    pub method: String,
    pub lambda_g: f32,
    pub d: usize,
    pub n_params: usize,
}

impl JobSpec {
    pub fn from_config(config: &TrainConfig) -> Self {
        JobSpec {
            family: config.family.clone(),
            method: config.method.clone(),
            lambda_g: config.lambda_g,
            d: config.d,
            n_params: Mlp::n_params_for(config.d),
        }
    }
}

fn encode_hello(spec: &JobSpec) -> Vec<u8> {
    let mut e = Enc::default();
    e.u32(PROTOCOL_VERSION);
    e.str(&spec.family);
    e.str(&spec.method);
    e.f32(spec.lambda_g);
    e.u64(spec.d as u64);
    e.u64(spec.n_params as u64);
    e.buf
}

/// Point span `[base, end)` of shard range `lo..hi` in an `n`-point
/// plan.  Shared by rank 0 (to slice the xs broadcast) and the worker
/// (to validate and rebase) so the two sides cannot disagree.
fn point_span(lo: usize, hi: usize, n: usize) -> (usize, usize) {
    let n_shards = n.div_ceil(CHUNK_POINTS);
    let base = (lo * CHUNK_POINTS).min(n);
    let end = if hi == n_shards { n } else { (hi * CHUNK_POINTS).min(n) };
    (base, end)
}

/// Params, probes and coeff go to every worker; the residual points do
/// not — each worker receives only the contiguous xs slice its shard
/// assignment covers (the dominant per-point broadcast cost scales as
/// `n·d` total instead of `workers·n·d`).  Slicing changes no bits:
/// the worker rebases its shards onto the slice, and every shard reads
/// exactly the floats it would have read from the full batch.  Encodes
/// into a caller-owned buffer so the per-step broadcast allocates
/// nothing at steady state.
fn encode_step_into(
    e: &mut Enc,
    step: u64,
    range: &Range<usize>,
    params: &[f32],
    batch: &NativeBatch,
    d: usize,
) {
    let (base, end) = point_span(range.start, range.end, batch.n);
    e.buf.clear();
    e.u64(step);
    e.u64(range.start as u64);
    e.u64(range.end as u64);
    e.u64(batch.n as u64);
    e.u64(batch.v as u64);
    e.u64(CHUNK_POINTS as u64);
    e.u64(base as u64);
    e.f32s(params);
    e.f32s(&batch.xs[base * d..end * d]);
    e.f32s(batch.probes);
    e.f32s(batch.coeff);
}

// ---------------------------------------------------------------------------
// Rank 0: the cluster backend
// ---------------------------------------------------------------------------

struct WorkerConn {
    stream: TcpStream,
    addr: String,
}

/// `TcpStream::connect` with the module's timeout (the OS default can
/// block for minutes against a black-holed address); tries every
/// resolved socket address.
fn connect_worker(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let resolved: Vec<SocketAddr> = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving worker address {addr}"))?
        .collect();
    let mut last_err: Option<std::io::Error> = None;
    for sa in &resolved {
        match TcpStream::connect_timeout(sa, timeout) {
            Ok(stream) => return Ok(stream),
            Err(e) => last_err = Some(e),
        }
    }
    Err(match last_err {
        Some(e) => anyhow::Error::from(e).context(format!("connecting to worker {addr}")),
        None => anyhow::anyhow!("worker address {addr} resolved to no socket addresses"),
    })
}

/// [`ShardBackend`] over TCP worker processes.  Connect once with a
/// [`JobSpec`]; each step broadcasts the packed parameters + sampled
/// batch with a contiguous shard assignment per worker, then gathers
/// per-shard results.  The caller's shard-index-ordered merge makes the
/// reduction bitwise identical to a single-process run for any worker
/// count (same-ISA caveat: DESIGN.md §10).
pub struct TcpClusterBackend {
    conns: Vec<WorkerConn>,
    spec: JobSpec,
    /// Operator name every worker resolved during the handshake.
    op_name: String,
    step: u64,
    params_buf: Vec<f32>,
    step_buf: Enc,
}

impl TcpClusterBackend {
    /// Connect to `addrs` and handshake the job spec with each worker.
    pub fn connect(addrs: &[String], spec: JobSpec) -> Result<Self> {
        if addrs.is_empty() {
            bail!("a worker cluster needs at least one worker address");
        }
        let timeout = worker_timeout();
        let mut conns = Vec::new();
        let mut op_name: Option<String> = None;
        for addr in addrs {
            let stream = connect_worker(addr, timeout)?;
            stream.set_nodelay(true).ok();
            // both directions: a wedged peer must error out, not block
            // write_all forever (the read timeout alone would not cover
            // a full TCP send buffer)
            stream.set_read_timeout(Some(timeout)).ok();
            stream.set_write_timeout(Some(timeout)).ok();
            let mut conn = WorkerConn { stream, addr: addr.clone() };
            write_frame(&mut conn.stream, TAG_HELLO, &encode_hello(&spec))
                .with_context(|| format!("sending the job spec to worker {addr}"))?;
            let (tag, payload) = read_frame(&mut conn.stream)
                .with_context(|| format!("waiting for worker {addr}'s handshake ack"))?;
            match tag {
                TAG_HELLO_ACK => {
                    let mut d = Dec::new(&payload);
                    let name = d.str()?.to_string();
                    let chunk = d.u64()? as usize;
                    let _worker_threads = d.u64()?;
                    if chunk != CHUNK_POINTS {
                        bail!(
                            "worker {addr} shards batches into {chunk}-point chunks but this \
                             coordinator uses {CHUNK_POINTS} — mixed binary versions would \
                             break the bitwise shard plan"
                        );
                    }
                    match &op_name {
                        None => op_name = Some(name),
                        Some(expect) if *expect == name => {}
                        Some(expect) => bail!(
                            "worker {addr} resolved operator {name} but earlier workers \
                             resolved {expect} — mixed worker builds?"
                        ),
                    }
                }
                TAG_ERROR => {
                    let mut d = Dec::new(&payload);
                    bail!("worker {addr} rejected the job spec: {}", d.str()?);
                }
                other => bail!("worker {addr} sent unexpected frame tag {other} during handshake"),
            }
            conns.push(conn);
        }
        Ok(Self {
            conns,
            spec,
            op_name: op_name.expect("at least one worker acked"),
            step: 0,
            params_buf: Vec::new(),
            step_buf: Enc::default(),
        })
    }

    pub fn workers(&self) -> usize {
        self.conns.len()
    }
}

fn decode_result_into(
    payload: &[u8],
    step: u64,
    range: &Range<usize>,
    addr: &str,
    out: &mut [ShardResult],
    filled: &mut [bool],
) -> Result<()> {
    let mut d = Dec::new(payload);
    let echo = d.u64()?;
    if echo != step {
        bail!("worker {addr} answered step {echo}, expected step {step} — protocol out of sync");
    }
    let count = d.u64()? as usize;
    if count != range.len() {
        bail!(
            "worker {addr} returned {count} shards, expected {} (assignment {range:?})",
            range.len()
        );
    }
    for _ in 0..count {
        let index = d.u64()? as usize;
        if !range.contains(&index) {
            bail!("worker {addr} returned shard {index} outside its assignment {range:?}");
        }
        if filled[index] {
            bail!("worker {addr} returned shard {index} twice");
        }
        let loss = d.f64()?;
        let slot = &mut out[index];
        slot.index = index;
        slot.loss = loss;
        d.f32s_into(&mut slot.grad)?;
        filled[index] = true;
    }
    Ok(())
}

impl ShardBackend for TcpClusterBackend {
    fn run_shards(
        &mut self,
        plan: &ShardPlan,
        job: &ShardJob,
        out: &mut Vec<ShardResult>,
    ) -> Result<()> {
        if job.op.name() != self.op_name {
            bail!(
                "cluster workers were configured for the {} operator (method {:?}) but this \
                 step runs {} — reconnect the cluster with the matching job spec",
                self.op_name,
                self.spec.method,
                job.op.name()
            );
        }
        if let Some(lambda) = job.op.lambda_g() {
            // compare bits: the workers rebuilt their operator from the
            // spec's exact f32
            if lambda.to_bits() != self.spec.lambda_g.to_bits() {
                bail!(
                    "this step's {} operator has lambda_g = {lambda} but the cluster was \
                     handshaken with {} — reconnect with the matching job spec",
                    job.op.name(),
                    self.spec.lambda_g
                );
            }
        }
        let n_params = job.mlp.n_params();
        if n_params != self.spec.n_params {
            bail!(
                "job has {n_params} parameters but the cluster was connected for {} — \
                 reconnect with the matching job spec",
                self.spec.n_params
            );
        }
        let n_tasks = plan.len();
        prepare_results(out, n_tasks);
        self.step += 1;
        let step = self.step;
        self.params_buf.resize(n_params, 0.0);
        job.mlp.pack_into(&mut self.params_buf);
        let ranges = plan.assignment(self.conns.len());
        // Broadcast first: every worker starts computing while rank 0 is
        // still writing to the next one.
        for (conn, range) in self.conns.iter_mut().zip(&ranges) {
            let d = self.spec.d;
            encode_step_into(&mut self.step_buf, step, range, &self.params_buf, job.batch, d);
            write_frame(&mut conn.stream, TAG_STEP, &self.step_buf.buf).with_context(|| {
                format!(
                    "sending step {step} (shards {range:?}) to worker {} — did the worker die?",
                    conn.addr
                )
            })?;
        }
        // Gather; merge ordering is the caller's shard-index reduction,
        // so gather order only affects latency, never bits.
        let mut filled = vec![false; n_tasks];
        for (conn, range) in self.conns.iter_mut().zip(&ranges) {
            let (tag, payload) = read_frame(&mut conn.stream).with_context(|| {
                format!(
                    "waiting for step-{step} results from worker {} (shards {range:?}) — if \
                     the worker died, restart it and rerun",
                    conn.addr
                )
            })?;
            match tag {
                TAG_RESULT => {
                    decode_result_into(&payload, step, range, &conn.addr, out, &mut filled)?
                }
                TAG_ERROR => {
                    let mut d = Dec::new(&payload);
                    bail!("worker {} failed on step {step}: {}", conn.addr, d.str()?);
                }
                other => bail!("worker {} sent unexpected frame tag {other}", conn.addr),
            }
        }
        if let Some(missing) = filled.iter().position(|f| !f) {
            bail!("no worker returned shard {missing} of step {step}");
        }
        Ok(())
    }

    fn parallelism(&self) -> usize {
        self.conns.len()
    }

    fn label(&self) -> String {
        format!("tcp-cluster(workers={})", self.conns.len())
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

struct WorkerState {
    mlp: Mlp,
    problem: Box<dyn PdeProblem>,
    op: Box<dyn ResidualOp>,
    backend: super::shard::InProcessBackend,
    results: Vec<ShardResult>,
    n_params: usize,
    d: usize,
    // persistent per-step scratch (mirrors rank 0's recycled buffers:
    // at steady state a worker step performs no payload allocation)
    params: Vec<f32>,
    xs: Vec<f32>,
    probes: Vec<f32>,
    coeff: Vec<f32>,
    reply: Enc,
}

fn build_state(
    family: &str,
    method: &str,
    lambda_g: f32,
    d: usize,
    n_params: usize,
    threads: usize,
) -> Result<WorkerState> {
    let problem = problem_for(family, d)?;
    let op = residual_op_for(problem.as_ref(), method, lambda_g)?;
    let expect = Mlp::n_params_for(d);
    if n_params != expect {
        bail!(
            "coordinator expects {n_params} parameters but this worker's MLP at d={d} has \
             {expect} — mixed binary versions?"
        );
    }
    // Weights are overwritten by the first STEP's params; the init
    // values never matter, so a fixed throwaway seed is fine.
    let mlp = Mlp::init(d, &mut Xoshiro256pp::new(0));
    Ok(WorkerState {
        mlp,
        problem,
        op,
        backend: super::shard::InProcessBackend::new(threads),
        results: Vec::new(),
        n_params,
        d,
        params: Vec::new(),
        xs: Vec::new(),
        probes: Vec::new(),
        coeff: Vec::new(),
        reply: Enc::default(),
    })
}

/// The fixed-size prefix of a STEP frame; the four float arrays decode
/// straight into [`WorkerState`]'s persistent scratch buffers.
struct StepHeader {
    step: u64,
    lo: usize,
    hi: usize,
    n: usize,
    v: usize,
    chunk: usize,
    /// First batch point covered by the xs slice (= the range's span).
    base: usize,
}

fn decode_step_into(payload: &[u8], st: &mut WorkerState) -> Result<StepHeader> {
    let mut d = Dec::new(payload);
    let header = StepHeader {
        step: d.u64()?,
        lo: d.u64()? as usize,
        hi: d.u64()? as usize,
        n: d.u64()? as usize,
        v: d.u64()? as usize,
        chunk: d.u64()? as usize,
        base: d.u64()? as usize,
    };
    d.f32s_into(&mut st.params)?;
    d.f32s_into(&mut st.xs)?;
    d.f32s_into(&mut st.probes)?;
    d.f32s_into(&mut st.coeff)?;
    Ok(header)
}

/// Run one STEP, leaving the RESULT payload in `st.reply`.
fn run_step(st: &mut WorkerState, payload: &[u8]) -> Result<()> {
    let h = decode_step_into(payload, st)?;
    if h.chunk != CHUNK_POINTS {
        bail!(
            "coordinator shards into {}-point chunks, this worker uses {CHUNK_POINTS} — \
             mixed binary versions would break the bitwise shard plan",
            h.chunk
        );
    }
    if st.params.len() != st.n_params {
        bail!("step carries {} parameters, job spec said {}", st.params.len(), st.n_params);
    }
    if st.probes.len() != h.v * st.d {
        bail!("probe matrix has {} coords for v={} at d={}", st.probes.len(), h.v, st.d);
    }
    if st.coeff.len() != st.problem.n_coeff() {
        bail!(
            "step carries {} solution coefficients, the {} problem has {}",
            st.coeff.len(),
            st.problem.family(),
            st.problem.n_coeff()
        );
    }
    let n_shards = h.n.div_ceil(CHUNK_POINTS);
    if h.lo > h.hi || h.hi > n_shards {
        bail!("shard range {}..{} outside the {n_shards}-shard plan", h.lo, h.hi);
    }
    // The coordinator ships only this assignment's xs slice; rebase the
    // shards onto it.  Same floats in the same order as the full-batch
    // plan, so the per-shard bits are unchanged.
    let (base, end) = point_span(h.lo, h.hi, h.n);
    if h.base != base {
        bail!("step's xs slice starts at point {} but the shard range implies {base}", h.base);
    }
    let n_local = end - base;
    if st.xs.len() != n_local * st.d {
        bail!("xs slice has {} coords for {n_local} points at d={}", st.xs.len(), st.d);
    }
    let local_plan = ShardPlan::with_chunk(n_local, CHUNK_POINTS);
    if local_plan.len() != h.hi - h.lo {
        bail!(
            "xs slice of {n_local} points yields {} shards, assignment {}..{} expects {}",
            local_plan.len(),
            h.lo,
            h.hi,
            h.hi - h.lo
        );
    }
    st.mlp.unpack_into(&st.params);
    let batch =
        NativeBatch { xs: &st.xs, probes: &st.probes, coeff: &st.coeff, n: n_local, v: h.v };
    let job = ShardJob {
        mlp: &st.mlp,
        problem: st.problem.as_ref(),
        op: st.op.as_ref(),
        batch: &batch,
    };
    st.backend.run_shards(&local_plan, &job, &mut st.results)?;
    st.reply.buf.clear();
    st.reply.u64(h.step);
    st.reply.u64(st.results.len() as u64);
    for r in &st.results {
        // local shard j is global shard lo + j
        st.reply.u64((h.lo + r.index) as u64);
        st.reply.f64(r.loss);
        st.reply.f32s(&r.grad);
    }
    Ok(())
}

fn handle_coordinator(mut stream: TcpStream, threads: usize) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Same generous timeout rank 0 uses, on both directions: a
    // coordinator silent (or not reading) for that long is presumed
    // dead (power loss, partition), the session ends with a logged
    // error and the worker returns to accepting — a half-open
    // connection can never wedge the worker's sequential accept loop.
    stream.set_read_timeout(Some(worker_timeout())).ok();
    stream.set_write_timeout(Some(worker_timeout())).ok();
    let Some((tag, payload)) = read_frame_or_eof(&mut stream)? else {
        return Ok(()); // connected and left without a word (port scan)
    };
    if tag != TAG_HELLO {
        let _ = send_error(&mut stream, "expected a hello frame");
        bail!("expected a hello frame, got tag {tag}");
    }
    let mut d = Dec::new(&payload);
    let version = d.u32()?;
    if version != PROTOCOL_VERSION {
        let msg = format!(
            "coordinator speaks shard protocol v{version}, this worker speaks \
             v{PROTOCOL_VERSION}"
        );
        let _ = send_error(&mut stream, &msg);
        bail!("{msg}");
    }
    let family = d.str()?.to_string();
    let method = d.str()?.to_string();
    let lambda_g = d.f32()?;
    let dim = d.u64()? as usize;
    let n_params = d.u64()? as usize;
    let mut st = match build_state(&family, &method, lambda_g, dim, n_params, threads) {
        Ok(st) => st,
        Err(e) => {
            // ship the full context chain — this is how `problem_for` /
            // `residual_op_for` supported-set errors reach the operator
            let _ = send_error(&mut stream, &format!("{e:#}"));
            return Err(e);
        }
    };
    let mut ack = Enc::default();
    ack.str(st.op.name());
    ack.u64(CHUNK_POINTS as u64);
    ack.u64(threads as u64);
    write_frame(&mut stream, TAG_HELLO_ACK, &ack.buf).context("sending hello ack")?;
    loop {
        let Some((tag, payload)) = read_frame_or_eof(&mut stream)? else {
            return Ok(()); // clean goodbye: coordinator closed
        };
        match tag {
            TAG_STEP => match run_step(&mut st, &payload) {
                Ok(()) => write_frame(&mut stream, TAG_RESULT, &st.reply.buf)
                    .context("sending results")?,
                Err(e) => {
                    send_error(&mut stream, &format!("{e:#}")).context("sending error")?;
                    return Err(e);
                }
            },
            other => {
                let _ = send_error(&mut stream, &format!("unexpected frame tag {other}"));
                bail!("unexpected frame tag {other}");
            }
        }
    }
}

/// Blocking worker loop behind `hte-pinn worker --listen`: accept
/// coordinators one at a time, forever.  Each coordinator session runs
/// its shards with `threads` in-process worker threads (the thread
/// count never changes the bits — see [`ShardPlan`]).
pub fn serve(listener: TcpListener, threads: usize) -> Result<()> {
    serve_conns(listener, threads, None)
}

/// Like [`serve`], stopping after `max_conns` coordinator sessions
/// when given — tests run loopback workers on in-process threads this
/// way.
pub fn serve_conns(listener: TcpListener, threads: usize, max_conns: Option<usize>) -> Result<()> {
    let mut served = 0usize;
    for stream in listener.incoming() {
        let stream = stream.context("accepting a coordinator connection")?;
        let peer = match stream.peer_addr() {
            Ok(addr) => addr.to_string(),
            Err(_) => "?".into(),
        };
        if let Err(e) = handle_coordinator(stream, threads) {
            eprintln!("worker: session with {peer} ended with an error: {e:#}");
        }
        served += 1;
        if let Some(max) = max_conns {
            if served >= max {
                break;
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Local worker processes (`train --workers N`)
// ---------------------------------------------------------------------------

/// `N` `hte-pinn worker` child processes on loopback ports, spawned for
/// `train --workers N` and killed on drop.  Each child prints
/// `listening on <addr>` once bound (port 0 = kernel-assigned), which
/// is how the parent learns the addresses without a port race.
pub struct LocalWorkerPool {
    children: Vec<Child>,
    /// Kept open so a worker writing to stdout never hits a closed pipe.
    _stdouts: Vec<BufReader<ChildStdout>>,
    pub addrs: Vec<String>,
}

impl LocalWorkerPool {
    /// Spawn from the currently running binary (the `train` path).
    pub fn spawn(n: usize, threads: usize) -> Result<Self> {
        let exe = std::env::current_exe().context("locating the hte-pinn binary")?;
        Self::spawn_with(&exe, n, threads)
    }

    /// Spawn from an explicit binary path (tests use
    /// `env!("CARGO_BIN_EXE_hte-pinn")`).
    pub fn spawn_with(program: &Path, n: usize, threads: usize) -> Result<Self> {
        if n == 0 {
            bail!("--workers needs at least 1 worker process");
        }
        let mut pool =
            LocalWorkerPool { children: Vec::new(), _stdouts: Vec::new(), addrs: Vec::new() };
        for i in 0..n {
            let mut child = Command::new(program)
                .args(["worker", "--listen", "127.0.0.1:0", "--threads"])
                .arg(threads.to_string())
                .stdout(Stdio::piped())
                .spawn()
                .with_context(|| format!("spawning local worker {i} from {program:?}"))?;
            let stdout = child.stdout.take().expect("stdout was piped");
            let mut reader = BufReader::new(stdout);
            let mut line = String::new();
            reader
                .read_line(&mut line)
                .with_context(|| format!("reading local worker {i}'s listen address"))?;
            let Some(addr) = line.trim().strip_prefix("listening on ") else {
                let _ = child.kill();
                bail!("local worker {i} printed {line:?} instead of its listen address");
            };
            pool.addrs.push(addr.to_string());
            pool.children.push(child);
            pool._stdouts.push(reader);
        }
        Ok(pool)
    }

    /// Kill worker `i` (the error-path tests: a dead worker must surface
    /// a clear diagnostic, not a hang).
    pub fn kill_one(&mut self, i: usize) {
        if let Some(child) = self.children.get_mut(i) {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

impl Drop for LocalWorkerPool {
    fn drop(&mut self) {
        for child in &mut self.children {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativeTrainer;
    use crate::estimators::Estimator;
    use crate::nn::{default_residual_op, NativeEngine};
    use crate::pde::{Domain, DomainSampler};
    use crate::rng::{fill_rademacher, Normal};

    /// Loopback worker on an in-process thread: real TCP, no child
    /// process.  Serves `conns` coordinator sessions then exits.
    fn spawn_test_worker(threads: usize, conns: usize) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let addr = listener.local_addr().expect("local addr").to_string();
        std::thread::spawn(move || {
            let _ = serve_conns(listener, threads, Some(conns));
        });
        addr
    }

    fn train_config(family: &str, method: &str, d: usize, epochs: usize) -> TrainConfig {
        let estimator =
            if family == "bihar" { Estimator::HteGaussian } else { Estimator::HteRademacher };
        TrainConfig {
            family: family.into(),
            method: method.into(),
            estimator,
            d,
            v: 4,
            epochs,
            lr0: 2e-3,
            seed: 5,
            lambda_g: 10.0,
            log_every: usize::MAX,
        }
    }

    /// The xs-slice spans of a step's assignments tile the batch
    /// exactly: contiguous, disjoint, complete — for any worker count.
    #[test]
    fn shard_point_spans_tile_the_batch() {
        for n in [1usize, 4, 5, 11, 16, 17] {
            let plan = ShardPlan::for_batch(n);
            for workers in 1..=4 {
                let mut next = 0usize;
                for r in plan.assignment(workers) {
                    let (base, end) = point_span(r.start, r.end, n);
                    if r.is_empty() {
                        assert_eq!(base, end, "empty assignment must get an empty span");
                    } else {
                        assert_eq!(base, next, "n={n} workers={workers}: span gap");
                        assert!(end > base);
                        next = end;
                    }
                }
                assert_eq!(next, n, "n={n} workers={workers}: spans must cover the batch");
            }
        }
    }

    /// The worker-side rebasing invariant the bitwise guarantee rests
    /// on: a local plan over an assignment's xs slice has exactly the
    /// global slice's shards, shifted by the span base.
    #[test]
    fn shard_local_rebased_plan_matches_global_slice() {
        for n in [1usize, 5, 11, 16] {
            let plan = ShardPlan::for_batch(n);
            for workers in 1..=3 {
                for r in plan.assignment(workers) {
                    let (base, end) = point_span(r.start, r.end, n);
                    let local = ShardPlan::with_chunk(end - base, CHUNK_POINTS);
                    assert_eq!(local.len(), r.len());
                    let global = &plan.shards()[r.clone()];
                    for (j, (ls, gs)) in local.shards().iter().zip(global).enumerate() {
                        assert_eq!(ls.index, j, "local indices start at 0");
                        assert_eq!(base + ls.start, gs.start, "rebased start must agree");
                        assert_eq!(ls.nc, gs.nc, "shard sizes must agree");
                    }
                }
            }
        }
    }

    #[test]
    fn wire_roundtrip_is_bit_exact() {
        let mut e = Enc::default();
        e.u32(7);
        e.str("sg2");
        e.f32(f32::from_bits(0x7f80_0001)); // a signaling NaN survives
        e.f64(-0.0);
        e.f32s(&[1.5, -2.25, f32::NEG_INFINITY]);
        let mut d = Dec::new(&e.buf);
        assert_eq!(d.u32().unwrap(), 7);
        assert_eq!(d.str().unwrap(), "sg2");
        assert_eq!(d.f32().unwrap().to_bits(), 0x7f80_0001);
        assert_eq!(d.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        let xs = d.f32s().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2], f32::NEG_INFINITY);
        // over-reading is a clean error, not a panic
        assert!(d.u64().is_err());
    }

    /// The acceptance gate: engine-level loss + full gradient over the
    /// TCP cluster backend are bitwise identical to the in-process
    /// backend, for every residual family and multiple worker counts.
    #[test]
    fn shard_cluster_loopback_matches_in_process_bitwise() {
        for (family, method, domain, gaussian) in [
            ("sg2", "probe", Domain::UnitBall, false),
            ("bihar", "probe4", Domain::Annulus, true),
            ("ac2", "hte", Domain::UnitBall, false),
        ] {
            let (d, n, v) = (4usize, 11usize, 4usize);
            let mut rng = Xoshiro256pp::new(61);
            let mlp = Mlp::init(d, &mut rng);
            let problem = problem_for(family, d).unwrap();
            let mut sampler = DomainSampler::new(domain, d, rng.fork(1));
            let xs = sampler.batch(n);
            let mut probes = vec![0.0f32; v * d];
            if gaussian {
                Normal::new().fill_f32(&mut rng, &mut probes);
            } else {
                fill_rademacher(&mut rng, &mut probes);
            }
            let mut coeff = vec![0.0f32; problem.n_coeff()];
            Normal::new().fill_f32(&mut rng, &mut coeff);
            let batch = NativeBatch { xs: &xs, probes: &probes, coeff: &coeff, n, v };
            let op = default_residual_op(problem.as_ref());

            let mut ref_engine = NativeEngine::new(3);
            let mut ref_grad = Vec::new();
            let ref_loss = ref_engine
                .loss_and_grad_with(&mlp, problem.as_ref(), op, &batch, &mut ref_grad)
                .unwrap();

            let mut cfg = train_config(family, method, d, 1);
            cfg.v = v;
            for workers in [1usize, 2, 3] {
                let addrs: Vec<String> = (0..workers).map(|_| spawn_test_worker(2, 1)).collect();
                let backend =
                    TcpClusterBackend::connect(&addrs, JobSpec::from_config(&cfg)).unwrap();
                let mut engine = NativeEngine::with_backend(Box::new(backend));
                assert_eq!(engine.threads(), workers);
                let mut grad = Vec::new();
                let loss = engine
                    .loss_and_grad_with(&mlp, problem.as_ref(), op, &batch, &mut grad)
                    .unwrap();
                assert_eq!(
                    loss.to_bits(),
                    ref_loss.to_bits(),
                    "{family}: loss differs over tcp with {workers} workers"
                );
                assert_eq!(grad.len(), ref_grad.len());
                for (a, b) in grad.iter().zip(&ref_grad) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{family}: gradient differs over tcp with {workers} workers"
                    );
                }
            }
        }
    }

    /// Whole-trainer parity: N steps of Adam over a 2-worker loopback
    /// cluster leave byte-identical parameters vs in-process threads.
    #[test]
    fn shard_cluster_trainer_steps_match_in_process_bitwise() {
        let cfg = train_config("sg2", "probe", 5, 8);
        let mut local = NativeTrainer::with_threads(cfg.clone(), 9, 3).unwrap();
        let addrs: Vec<String> = (0..2).map(|_| spawn_test_worker(2, 1)).collect();
        let backend = TcpClusterBackend::connect(&addrs, JobSpec::from_config(&cfg)).unwrap();
        let mut remote = NativeTrainer::with_backend(cfg, 9, Box::new(backend)).unwrap();
        assert!(remote.executor().contains("tcp-cluster"));
        for _ in 0..8 {
            local.step().unwrap();
            remote.step().unwrap();
        }
        assert_eq!(local.last_loss.to_bits(), remote.last_loss.to_bits());
        let (a, b) = (local.mlp.pack(), remote.mlp.pack());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "parameters diverged over the cluster");
        }
    }

    /// A worker that dies mid-run must surface a diagnostic naming the
    /// worker — never hang the training loop.
    #[test]
    fn shard_cluster_dead_worker_is_a_clear_error() {
        // this "worker" acks the handshake, then drops the connection
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let Ok(Some((tag, _payload))) = read_frame_or_eof(&mut stream) else { return };
            assert_eq!(tag, TAG_HELLO);
            let mut ack = Enc::default();
            ack.str("trace");
            ack.u64(CHUNK_POINTS as u64);
            ack.u64(1);
            let _ = write_frame(&mut stream, TAG_HELLO_ACK, &ack.buf);
            // connection drops here — the coordinator's next read EOFs
        });
        let healthy = spawn_test_worker(1, 1);
        let cfg = train_config("sg2", "probe", 4, 1);
        let backend =
            TcpClusterBackend::connect(&[addr.clone(), healthy], JobSpec::from_config(&cfg))
                .unwrap();
        let mut trainer = NativeTrainer::with_backend(cfg, 9, Box::new(backend)).unwrap();
        let err = format!("{:#}", trainer.step().unwrap_err());
        assert!(err.contains("worker"), "diagnostic must name the worker: {err}");
        assert!(err.contains(&addr), "diagnostic must include the address: {err}");
    }

    /// An operator whose λ differs from the handshaken job spec must
    /// fail loudly, not silently train with the workers' λ.
    #[test]
    fn shard_cluster_rejects_mismatched_lambda() {
        use crate::nn::GpinnResidual;
        let addr = spawn_test_worker(1, 1);
        let mut cfg = train_config("sg2", "gpinn", 4, 1);
        cfg.lambda_g = 10.0;
        let backend = TcpClusterBackend::connect(&[addr], JobSpec::from_config(&cfg)).unwrap();
        let mut engine = NativeEngine::with_backend(Box::new(backend));

        let (d, n, v) = (4usize, 5usize, 2usize);
        let mut rng = Xoshiro256pp::new(71);
        let mlp = Mlp::init(d, &mut rng);
        let problem = problem_for("sg2", d).unwrap();
        let mut sampler = DomainSampler::new(Domain::UnitBall, d, rng.fork(1));
        let xs = sampler.batch(n);
        let mut probes = vec![0.0f32; v * d];
        fill_rademacher(&mut rng, &mut probes);
        let mut coeff = vec![0.0f32; problem.n_coeff()];
        Normal::new().fill_f32(&mut rng, &mut coeff);
        let batch = NativeBatch { xs: &xs, probes: &probes, coeff: &coeff, n, v };

        let wrong = GpinnResidual { lambda: 5.0 };
        let mut grad = Vec::new();
        let err = engine
            .loss_and_grad_with(&mlp, problem.as_ref(), &wrong, &batch, &mut grad)
            .unwrap_err()
            .to_string();
        assert!(err.contains("lambda_g"), "{err}");
        // the matching operator goes through
        let right = GpinnResidual { lambda: 10.0 };
        engine.loss_and_grad_with(&mlp, problem.as_ref(), &right, &batch, &mut grad).unwrap();
    }

    /// A bad job spec is rejected during the handshake with the
    /// supported-set error text from the worker's own validation.
    #[test]
    fn shard_cluster_handshake_rejects_unknown_family_and_method() {
        let addr = spawn_test_worker(1, 1);
        let mut cfg = train_config("sg2", "probe", 4, 1);
        cfg.family = "sg9".into();
        let err = TcpClusterBackend::connect(&[addr], JobSpec::from_config(&cfg))
            .unwrap_err()
            .to_string();
        assert!(err.contains("sg9"), "{err}");
        assert!(err.contains("supported"), "{err}");

        let addr = spawn_test_worker(1, 1);
        let mut cfg = train_config("sg2", "probe", 4, 1);
        cfg.method = "probe4".into();
        let err = TcpClusterBackend::connect(&[addr], JobSpec::from_config(&cfg))
            .unwrap_err()
            .to_string();
        assert!(err.contains("probe4"), "{err}");
    }
}
