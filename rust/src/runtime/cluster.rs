//! TCP cluster transport for the shard layer: `hte-pinn worker` serve
//! loop, the rank-0 [`TcpClusterBackend`], and the framed wire protocol
//! between them (DESIGN.md §10).
//!
//! Design constraints, in order:
//!
//! 1. **Bitwise determinism.**  A worker runs the *same*
//!    [`shard_loss_grad`](crate::nn::shard_loss_grad) kernel on the
//!    *same* [`ShardPlan`] shards a local thread would, and returns
//!    per-shard results tagged by shard index; rank 0 merges them with
//!    the same shard-index-ordered reduction the in-process backend
//!    feeds.  Probe/batch randomness never leaves rank 0 — workers
//!    receive the sampled batch, so RNG streams (and checkpoint-resume
//!    replay) are executor-independent by construction.  The guarantee
//!    holds across processes on the same ISA; heterogeneous ISAs differ
//!    in libm last bits (DESIGN.md §9).
//! 2. **No hangs, no lost runs.**  Every frame is length-prefixed and
//!    every socket phase carries its own deadline ([`Deadlines`]:
//!    connect/handshake 10 s, step 600 s — `HTE_WORKER_TIMEOUT_SECS`
//!    still works as a blanket override).  A worker that dies, wedges,
//!    or answers garbage mid-step is marked dead and its shards are
//!    reassigned to the survivors within the same step ([`split_range`]
//!    over the live subset); since rank 0 merges by shard index, the
//!    reduced bits are identical to the no-failure run.  Dead addresses
//!    are re-dialed every [`ClusterOpts::rejoin_interval`] (a rejoin is
//!    just a fresh HELLO — worker state rebuilds from the job spec),
//!    and `train --workers N` respawns crashed children via
//!    [`LocalWorkerPool::respawn_addr`].  Only zero live workers aborts
//!    a step.  The fault-injection harness (`HTE_FAULT`, see
//!    [`super::fault`]) drives all of these paths in tests and CI.
//! 3. **No serde dependency.**  The container format is hand-rolled
//!    little-endian framing (`[magic u32][tag u8][len u64][payload]`)
//!    with f32/f64 values shipped as raw bit patterns — exactly the
//!    bits, nothing reinterpreted.
//!
//! Protocol (one coordinator per worker at a time):
//!
//! ```text
//! coordinator                         worker
//!   HELLO {version, family, method,
//!          lambda_g, d, n_params}  ->
//!                                  <- HELLO_ACK {op, chunk_points, threads}
//!                                     (or ERROR {message})
//!   per step:
//!   STEP {step, shard_lo..hi, n, v,
//!         chunk_points, base, params,
//!         xs-slice, probes, coeff} ->
//!                                  <- RESULT {step, [index, loss, grad]*}
//!                                     (or ERROR {message})
//!   (connection drop = goodbye)
//! ```

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdout, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{problem_for, TrainConfig};
use crate::nn::{plan_chunk_points, residual_op_for, Mlp, NativeBatch, ResidualOp, CHUNK_POINTS};
use crate::pde::PdeProblem;
use crate::rng::Xoshiro256pp;

use super::fault::{FaultAction, FaultPlan, FaultState};
use super::shard::{prepare_results, split_range, ShardBackend, ShardJob, ShardPlan, ShardResult};

/// Bumped whenever a frame layout changes; a version mismatch is a hard
/// handshake error (shipping shards to a differently-planned binary
/// would silently break the bitwise guarantee).
/// v2: ANSWER and STATS carry `model_version`/`ckpt_step` so clients
/// can assert which weights answered across a hot checkpoint reload.
pub const PROTOCOL_VERSION: u32 = 2;

pub(crate) const FRAME_MAGIC: u32 = 0x4854_4550; // "HTEP"
/// Hard cap against garbage peers / corrupted length words.
pub(crate) const MAX_FRAME: u64 = 1 << 33;

pub(crate) const TAG_HELLO: u8 = 1;
pub(crate) const TAG_HELLO_ACK: u8 = 2;
pub(crate) const TAG_STEP: u8 = 3;
pub(crate) const TAG_RESULT: u8 = 4;
pub(crate) const TAG_ERROR: u8 = 5;
// Inference tier (`runtime::serve`), same framing and HELLO handshake:
// a batched query, its answer (or graceful rejection), and the
// observability snapshot.  Tag values are shared across the whole
// protocol so a frame can never be mis-read across tiers.
pub(crate) const TAG_QUERY: u8 = 6;
pub(crate) const TAG_ANSWER: u8 = 7;
pub(crate) const TAG_STATS: u8 = 8;

pub(crate) fn env_secs(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|s| s.parse::<u64>().ok())
}

/// Per-phase socket deadlines, replacing the old single
/// `HTE_WORKER_TIMEOUT_SECS` blanket (a wedged worker should be caught
/// in seconds at connect/handshake, while a step may legitimately take
/// minutes on a huge shard).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Deadlines {
    /// TCP connect (default 10 s).
    pub connect: Duration,
    /// HELLO → HELLO_ACK exchange (default 10 s).
    pub handshake: Duration,
    /// STEP → RESULT round trip (default 600 s).
    pub step: Duration,
}

impl Deadlines {
    /// Resolve `[connect, handshake, step]` overrides against the
    /// legacy blanket value: an explicit per-phase value wins, then the
    /// legacy blanket, then the per-phase default.  Zero clamps to 1 s
    /// (a zero socket timeout means "block forever" to the OS).
    pub fn resolve(explicit: [Option<u64>; 3], legacy: Option<u64>) -> Self {
        let pick = |e: Option<u64>, default: u64| {
            Duration::from_secs(e.or(legacy).unwrap_or(default).max(1))
        };
        Deadlines {
            connect: pick(explicit[0], 10),
            handshake: pick(explicit[1], 10),
            step: pick(explicit[2], 600),
        }
    }

    /// `HTE_CONNECT_TIMEOUT_SECS` / `HTE_HANDSHAKE_TIMEOUT_SECS` /
    /// `HTE_STEP_TIMEOUT_SECS`, with `HTE_WORKER_TIMEOUT_SECS` still
    /// honored as the blanket fallback.
    pub fn from_env() -> Self {
        Self::resolve(
            [
                env_secs("HTE_CONNECT_TIMEOUT_SECS"),
                env_secs("HTE_HANDSHAKE_TIMEOUT_SECS"),
                env_secs("HTE_STEP_TIMEOUT_SECS"),
            ],
            env_secs("HTE_WORKER_TIMEOUT_SECS"),
        )
    }
}

/// Recovery knobs for [`TcpClusterBackend`]: how hard to try to reach a
/// worker, and how often to re-dial dead ones between steps.
#[derive(Clone, Debug)]
pub struct ClusterOpts {
    pub deadlines: Deadlines,
    /// Extra connect attempts after the first (exponential backoff with
    /// jitter).  Only transient failures retry — a worker that *answers*
    /// and rejects the job spec fails immediately.
    pub max_worker_retries: u32,
    /// How long a worker stays dead before rank 0 re-dials it at a step
    /// boundary.
    pub rejoin_interval: Duration,
}

impl ClusterOpts {
    /// `HTE_MAX_WORKER_RETRIES` (default 3) and
    /// `HTE_REJOIN_INTERVAL_SECS` (default 30) over
    /// [`Deadlines::from_env`].
    pub fn from_env() -> Self {
        ClusterOpts {
            deadlines: Deadlines::from_env(),
            max_worker_retries: env_secs("HTE_MAX_WORKER_RETRIES").unwrap_or(3) as u32,
            rejoin_interval: Duration::from_secs(env_secs("HTE_REJOIN_INTERVAL_SECS").unwrap_or(30)),
        }
    }
}

impl Default for ClusterOpts {
    fn default() -> Self {
        Self::from_env()
    }
}

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

pub(crate) fn addr_salt(addr: &str) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    addr.hash(&mut h);
    h.finish()
}

/// Exponential backoff (100 ms · 2^attempt, capped at 5 s) plus up to
/// 25% address-salted jitter so a fleet of coordinators re-dialing one
/// restarted worker doesn't stampede it in lockstep.  Shared with the
/// serve-tier router and loadgen, which re-dial replicas the same way.
pub(crate) fn backoff_delay(attempt: u32, salt: u64) -> Duration {
    let base = 100u64.saturating_mul(1 << attempt.min(6)).min(5_000);
    let jitter = splitmix64(salt ^ ((attempt as u64) << 32)) % (base / 4 + 1);
    Duration::from_millis(base + jitter)
}

// ---------------------------------------------------------------------------
// Wire encoding (hand-rolled little-endian, bit-exact floats)
// ---------------------------------------------------------------------------

#[derive(Default)]
pub(crate) struct Enc {
    pub(crate) buf: Vec<u8>,
}

impl Enc {
    pub(crate) fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    pub(crate) fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    pub(crate) fn f32(&mut self, x: f32) {
        self.u32(x.to_bits());
    }
    pub(crate) fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }
    pub(crate) fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    pub(crate) fn f32s(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        self.buf.reserve(xs.len() * 4);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }
    /// f64 array, raw bit patterns (the serve tier's answers are f64 —
    /// `forward_constrained` widens before the constraint factor).
    pub(crate) fn f64s(&mut self, xs: &[f64]) {
        self.u64(xs.len() as u64);
        self.buf.reserve(xs.len() * 8);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }
}

pub(crate) struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated frame payload: wanted {n} bytes at offset {}, frame has {}",
                self.pos,
                self.buf.len()
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }
    pub(crate) fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    pub(crate) fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
    pub(crate) fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    pub(crate) fn str(&mut self) -> Result<&'a str> {
        let n = self.u32()? as usize;
        std::str::from_utf8(self.bytes(n)?).context("non-UTF8 string in frame")
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.f32s_into(&mut out)?;
        Ok(out)
    }
    /// Decode into a caller-owned buffer (the rank-0 gather reuses each
    /// shard's gradient Vec across steps — no steady-state allocation).
    pub(crate) fn f32s_into(&mut self, out: &mut Vec<f32>) -> Result<()> {
        let n = self.u64()? as usize;
        let raw = self.bytes(n.checked_mul(4).context("absurd f32 array length")?)?;
        out.clear();
        out.reserve(n);
        out.extend(raw.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])));
        Ok(())
    }
    /// f64 counterpart of [`Dec::f32s_into`] (serve answers).
    pub(crate) fn f64s_into(&mut self, out: &mut Vec<f64>) -> Result<()> {
        let n = self.u64()? as usize;
        let raw = self.bytes(n.checked_mul(8).context("absurd f64 array length")?)?;
        out.clear();
        out.reserve(n);
        out.extend(raw.chunks_exact(8).map(|b| {
            f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
        }));
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

pub(crate) fn write_frame(stream: &mut TcpStream, tag: u8, payload: &[u8]) -> std::io::Result<()> {
    let mut head = [0u8; 13];
    head[..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    head[4] = tag;
    head[5..].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    stream.write_all(&head)?;
    stream.write_all(payload)?;
    stream.flush()
}

/// Read one frame; `Ok(None)` on a clean EOF *between* frames (the peer
/// said goodbye by closing), an error on anything torn mid-frame.
pub(crate) fn read_frame_or_eof(stream: &mut TcpStream) -> Result<Option<(u8, Vec<u8>)>> {
    let mut head = [0u8; 13];
    let mut got = 0usize;
    while got < head.len() {
        let k = stream.read(&mut head[got..]).context("reading frame header")?;
        if k == 0 {
            if got == 0 {
                return Ok(None);
            }
            bail!("peer closed the connection mid-frame header");
        }
        got += k;
    }
    let magic = u32::from_le_bytes([head[0], head[1], head[2], head[3]]);
    if magic != FRAME_MAGIC {
        bail!("bad frame magic {magic:#010x} — peer is not an hte-pinn shard endpoint");
    }
    let tag = head[4];
    let len = u64::from_le_bytes([
        head[5], head[6], head[7], head[8], head[9], head[10], head[11], head[12],
    ]);
    if len > MAX_FRAME {
        bail!("absurd frame length {len} (corrupted stream?)");
    }
    // Grow the payload buffer only as fast as bytes actually arrive: a
    // garbage peer sending a huge length word cannot make us pre-allocate
    // gigabytes — it would have to stream the bytes (and the read
    // timeout bounds how long it may take).
    let len = len as usize;
    const READ_CHUNK: usize = 1 << 20;
    let mut payload: Vec<u8> = Vec::with_capacity(len.min(READ_CHUNK));
    while payload.len() < len {
        let take = (len - payload.len()).min(READ_CHUNK);
        let start = payload.len();
        payload.resize(start + take, 0);
        stream
            .read_exact(&mut payload[start..])
            .context("peer closed the connection mid-frame")?;
    }
    Ok(Some((tag, payload)))
}

/// Read one frame, treating EOF as an error (rank 0 waiting on results).
pub(crate) fn read_frame(stream: &mut TcpStream) -> Result<(u8, Vec<u8>)> {
    read_frame_or_eof(stream)?
        .context("peer closed the connection (worker process died or was killed?)")
}

pub(crate) fn send_error(stream: &mut TcpStream, msg: &str) -> std::io::Result<()> {
    let mut e = Enc::default();
    e.str(msg);
    write_frame(stream, TAG_ERROR, &e.buf)
}

// ---------------------------------------------------------------------------
// Job spec (what a worker needs to rebuild problem/op/net)
// ---------------------------------------------------------------------------

/// Everything a worker needs to reconstruct the residual job locally:
/// the problem family, the method string (one shared
/// `residual_op_for` mapping on both ends), the gPINN weight, and the
/// dimensions to validate against.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    pub family: String,
    pub method: String,
    pub lambda_g: f32,
    pub d: usize,
    pub n_params: usize,
}

impl JobSpec {
    pub fn from_config(config: &TrainConfig) -> Self {
        JobSpec {
            family: config.family.clone(),
            method: config.method.clone(),
            lambda_g: config.lambda_g,
            d: config.d,
            n_params: Mlp::n_params_for(config.d),
        }
    }
}

pub(crate) fn encode_hello(spec: &JobSpec) -> Vec<u8> {
    let mut e = Enc::default();
    e.u32(PROTOCOL_VERSION);
    e.str(&spec.family);
    e.str(&spec.method);
    e.f32(spec.lambda_g);
    e.u64(spec.d as u64);
    e.u64(spec.n_params as u64);
    e.buf
}

/// Point span `[base, end)` of shard range `lo..hi` in an `n`-point
/// plan of `chunk`-point shards.  Shared by rank 0 (to slice the xs
/// broadcast) and the worker (to validate and rebase) so the two sides
/// cannot disagree.  `chunk` is the *effective* chunk — possibly shrunk
/// below [`CHUNK_POINTS`] by `HTE_ARENA_KB` (see `plan_chunk_points`) —
/// and travels in every STEP frame so a mismatch is caught per step.
fn point_span(lo: usize, hi: usize, n: usize, chunk: usize) -> (usize, usize) {
    let n_shards = n.div_ceil(chunk);
    let base = (lo * chunk).min(n);
    let end = if hi == n_shards { n } else { (hi * chunk).min(n) };
    (base, end)
}

/// Params, probes and coeff go to every worker; the residual points do
/// not — each worker receives only the contiguous xs slice its shard
/// assignment covers (the dominant per-point broadcast cost scales as
/// `n·d` total instead of `workers·n·d`).  Slicing changes no bits:
/// the worker rebases its shards onto the slice, and every shard reads
/// exactly the floats it would have read from the full batch.  Encodes
/// into a caller-owned buffer so the per-step broadcast allocates
/// nothing at steady state.
fn encode_step_into(
    e: &mut Enc,
    step: u64,
    range: &Range<usize>,
    params: &[f32],
    batch: &NativeBatch,
    d: usize,
    chunk: usize,
) {
    let (base, end) = point_span(range.start, range.end, batch.n, chunk);
    e.buf.clear();
    e.u64(step);
    e.u64(range.start as u64);
    e.u64(range.end as u64);
    e.u64(batch.n as u64);
    e.u64(batch.v as u64);
    e.u64(chunk as u64);
    e.u64(base as u64);
    e.f32s(params);
    e.f32s(&batch.xs[base * d..end * d]);
    e.f32s(batch.probes);
    e.f32s(batch.coeff);
}

// ---------------------------------------------------------------------------
// Rank 0: the cluster backend
// ---------------------------------------------------------------------------

/// One configured worker address and its connection state.  A slot with
/// `stream: None` is dead: its shards are reassigned to the survivors
/// and the address is re-dialed every [`ClusterOpts::rejoin_interval`].
struct WorkerSlot {
    addr: String,
    stream: Option<TcpStream>,
    /// Why the last session with this worker ended (for the all-dead
    /// diagnostic and rejoin logging).
    last_error: Option<String>,
    /// When the address was last dialed (throttles rejoin attempts).
    last_dial: Option<Instant>,
}

/// Handshake failure taxonomy: a worker that *answers* and says no is a
/// deterministic rejection (retrying cannot help, and the worker's own
/// message must surface verbatim); anything torn at the transport layer
/// may heal, so it retries with backoff.
enum DialError {
    Rejected(anyhow::Error),
    Transient(anyhow::Error),
}

impl DialError {
    fn into_inner(self) -> anyhow::Error {
        match self {
            DialError::Rejected(e) | DialError::Transient(e) => e,
        }
    }
}

/// Connect + HELLO handshake with one worker under the per-phase
/// deadlines; on success the stream carries the step deadline and the
/// worker's resolved operator name is returned.
fn dial(addr: &str, spec: &JobSpec, dl: &Deadlines) -> std::result::Result<(TcpStream, String), DialError> {
    let mut stream = connect_worker(addr, dl.connect).map_err(DialError::Transient)?;
    stream.set_nodelay(true).ok();
    // both directions: a wedged peer must error out, not block
    // write_all forever (the read timeout alone would not cover a full
    // TCP send buffer)
    stream.set_read_timeout(Some(dl.handshake)).ok();
    stream.set_write_timeout(Some(dl.handshake)).ok();
    write_frame(&mut stream, TAG_HELLO, &encode_hello(spec)).map_err(|e| {
        DialError::Transient(
            anyhow::Error::from(e).context(format!("sending the job spec to worker {addr}")),
        )
    })?;
    let (tag, payload) = read_frame(&mut stream)
        .map_err(|e| DialError::Transient(e.context(format!("waiting for worker {addr}'s handshake ack"))))?;
    match tag {
        TAG_HELLO_ACK => {
            let mut d = Dec::new(&payload);
            let parsed = (|| -> Result<(String, usize)> {
                let name = d.str()?.to_string();
                let chunk = d.u64()? as usize;
                let _worker_threads = d.u64()?;
                Ok((name, chunk))
            })();
            let (name, chunk) = parsed.map_err(DialError::Rejected)?;
            if chunk != CHUNK_POINTS {
                return Err(DialError::Rejected(anyhow::anyhow!(
                    "worker {addr} shards batches into {chunk}-point chunks but this \
                     coordinator uses {CHUNK_POINTS} — mixed binary versions would \
                     break the bitwise shard plan"
                )));
            }
            stream.set_read_timeout(Some(dl.step)).ok();
            stream.set_write_timeout(Some(dl.step)).ok();
            Ok((stream, name))
        }
        TAG_ERROR => {
            let mut d = Dec::new(&payload);
            let msg = d.str().unwrap_or("(unreadable error frame)");
            Err(DialError::Rejected(anyhow::anyhow!("worker {addr} rejected the job spec: {msg}")))
        }
        other => Err(DialError::Rejected(anyhow::anyhow!(
            "worker {addr} sent unexpected frame tag {other} during handshake"
        ))),
    }
}

/// [`dial`] with bounded retry: transient failures back off and try
/// again up to `opts.max_worker_retries` extra times; rejections fail
/// immediately with the worker's own message on top.
fn dial_retry(
    addr: &str,
    spec: &JobSpec,
    opts: &ClusterOpts,
) -> Result<(TcpStream, String)> {
    let mut attempt = 0u32;
    loop {
        match dial(addr, spec, &opts.deadlines) {
            Ok(ok) => return Ok(ok),
            Err(DialError::Rejected(e)) => return Err(e),
            Err(DialError::Transient(e)) => {
                if attempt >= opts.max_worker_retries {
                    return Err(e.context(format!(
                        "worker {addr} unreachable after {} connect attempt(s)",
                        attempt + 1
                    )));
                }
                let delay = backoff_delay(attempt, addr_salt(addr));
                eprintln!(
                    "[recovery] worker {addr} connect attempt {} failed ({e:#}); \
                     retrying in {delay:?}",
                    attempt + 1
                );
                std::thread::sleep(delay);
                attempt += 1;
            }
        }
    }
}

/// How one worker's part of a step ended, when it didn't end well.
enum StepFailure {
    /// Transport-level loss (EOF, timeout, garbage frame): mark the
    /// worker dead and reassign its shards to the survivors.
    Dead(String),
    /// The worker *answered* with a deterministic application error —
    /// every survivor would fail the same way, so abort the step.
    Fatal(anyhow::Error),
}

/// Hook the local worker pool installs so rank 0 can respawn a crashed
/// child process before re-dialing its address.  Returns `Ok(true)` if
/// a process was (re)started.
pub type RespawnHook = Box<dyn FnMut(&str) -> Result<bool> + Send>;

/// `TcpStream::connect` with the module's timeout (the OS default can
/// block for minutes against a black-holed address); tries every
/// resolved socket address.
pub(crate) fn connect_worker(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let resolved: Vec<SocketAddr> = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving worker address {addr}"))?
        .collect();
    let mut last_err: Option<std::io::Error> = None;
    for sa in &resolved {
        match TcpStream::connect_timeout(sa, timeout) {
            Ok(stream) => return Ok(stream),
            Err(e) => last_err = Some(e),
        }
    }
    Err(match last_err {
        Some(e) => anyhow::Error::from(e).context(format!("connecting to worker {addr}")),
        None => anyhow::anyhow!("worker address {addr} resolved to no socket addresses"),
    })
}

/// [`ShardBackend`] over TCP worker processes.  Connect once with a
/// [`JobSpec`]; each step broadcasts the packed parameters + sampled
/// batch with a contiguous shard assignment per worker, then gathers
/// per-shard results.  The caller's shard-index-ordered merge makes the
/// reduction bitwise identical to a single-process run for any worker
/// count (same-ISA caveat: DESIGN.md §10).
pub struct TcpClusterBackend {
    slots: Vec<WorkerSlot>,
    spec: JobSpec,
    /// Operator name every worker resolved during the handshake.
    op_name: String,
    opts: ClusterOpts,
    step: u64,
    params_buf: Vec<f32>,
    step_buf: Enc,
    /// Recovery events (deaths, rejoins, respawns) since the last
    /// [`ShardBackend::take_events`] drain.
    events: Vec<String>,
    respawner: Option<RespawnHook>,
}

impl TcpClusterBackend {
    /// Connect to `addrs` and handshake the job spec with each worker,
    /// with recovery knobs from the environment.
    pub fn connect(addrs: &[String], spec: JobSpec) -> Result<Self> {
        Self::connect_with(addrs, spec, ClusterOpts::default())
    }

    /// [`TcpClusterBackend::connect`] with explicit recovery knobs.
    pub fn connect_with(addrs: &[String], spec: JobSpec, opts: ClusterOpts) -> Result<Self> {
        if addrs.is_empty() {
            bail!("a worker cluster needs at least one worker address");
        }
        let mut slots = Vec::new();
        let mut op_name: Option<String> = None;
        for addr in addrs {
            let (stream, name) = dial_retry(addr, &spec, &opts)?;
            match &op_name {
                None => op_name = Some(name),
                Some(expect) if *expect == name => {}
                Some(expect) => bail!(
                    "worker {addr} resolved operator {name} but earlier workers \
                     resolved {expect} — mixed worker builds?"
                ),
            }
            slots.push(WorkerSlot {
                addr: addr.clone(),
                stream: Some(stream),
                last_error: None,
                last_dial: None,
            });
        }
        Ok(Self {
            slots,
            spec,
            op_name: op_name.expect("at least one worker acked"),
            opts,
            step: 0,
            params_buf: Vec::new(),
            step_buf: Enc::default(),
            events: Vec::new(),
            respawner: None,
        })
    }

    /// Configured workers (live or dead — a dead one may rejoin).
    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// Workers with a live connection right now.
    pub fn live_workers(&self) -> usize {
        self.slots.iter().filter(|s| s.stream.is_some()).count()
    }

    /// Install the hook that restarts crashed local worker processes
    /// before their address is re-dialed (`train --workers N`).
    pub fn set_respawner(&mut self, hook: RespawnHook) {
        self.respawner = Some(hook);
    }

    fn mark_dead(&mut self, si: usize, step: u64, reason: &str) {
        let slot = &mut self.slots[si];
        slot.stream = None;
        slot.last_error = Some(reason.to_string());
        slot.last_dial = Some(Instant::now());
        let event = format!(
            "step {step}: worker {} dead ({reason}); shards reassigned to survivors",
            slot.addr
        );
        eprintln!("[recovery] {event}");
        self.events.push(event);
    }

    /// Between steps, re-dial every dead address whose rejoin interval
    /// has elapsed — replaying the HELLO handshake rebuilds all worker
    /// state from the job spec, so rejoin is just a fresh session.
    fn try_rejoin(&mut self, step: u64) {
        for i in 0..self.slots.len() {
            if self.slots[i].stream.is_some() {
                continue;
            }
            let due = match self.slots[i].last_dial {
                None => true,
                Some(at) => at.elapsed() >= self.opts.rejoin_interval,
            };
            if !due {
                continue;
            }
            self.slots[i].last_dial = Some(Instant::now());
            let addr = self.slots[i].addr.clone();
            if let Some(hook) = self.respawner.as_mut() {
                match hook(&addr) {
                    Ok(true) => {
                        let event = format!("step {step}: respawned local worker {addr}");
                        eprintln!("[recovery] {event}");
                        self.events.push(event);
                    }
                    Ok(false) => {}
                    Err(e) => {
                        self.slots[i].last_error = Some(format!("respawn failed: {e:#}"));
                        continue;
                    }
                }
            }
            match dial(&addr, &self.spec, &self.opts.deadlines) {
                Ok((stream, name)) => {
                    if name != self.op_name {
                        self.slots[i].last_error = Some(format!(
                            "rejoined resolving operator {name}, cluster runs {} — \
                             mixed worker builds?",
                            self.op_name
                        ));
                        continue;
                    }
                    self.slots[i].stream = Some(stream);
                    self.slots[i].last_error = None;
                    let event = format!("step {step}: worker {addr} rejoined");
                    eprintln!("[recovery] {event}");
                    self.events.push(event);
                }
                Err(e) => {
                    self.slots[i].last_error =
                        Some(format!("rejoin failed: {:#}", e.into_inner()));
                }
            }
        }
    }

    fn all_dead_error(&self, step: u64) -> anyhow::Error {
        let mut lines = String::new();
        for s in &self.slots {
            lines.push_str(&format!(
                "\n  worker {}: {}",
                s.addr,
                s.last_error.as_deref().unwrap_or("dead")
            ));
        }
        anyhow::anyhow!(
            "all {} cluster workers are dead at step {step} — no survivors to \
             reassign shards to:{lines}",
            self.slots.len()
        )
    }

    /// Read one worker's RESULT for its part of a step, classifying any
    /// failure as [`StepFailure::Dead`] (reassign) or
    /// [`StepFailure::Fatal`] (abort).
    fn gather_one(
        &mut self,
        si: usize,
        step: u64,
        range: &Range<usize>,
        out: &mut [ShardResult],
        filled: &mut [bool],
    ) -> std::result::Result<(), StepFailure> {
        let slot = &mut self.slots[si];
        let stream = slot.stream.as_mut().expect("gather from a live slot");
        let (tag, payload) = match read_frame(stream) {
            Ok(frame) => frame,
            Err(e) => {
                return Err(StepFailure::Dead(format!(
                    "waiting for step-{step} results (shards {range:?}): {e:#}"
                )))
            }
        };
        match tag {
            TAG_RESULT => match decode_result_into(&payload, step, range, &slot.addr, out, filled)
            {
                Ok(()) => Ok(()),
                Err(e) => Err(StepFailure::Dead(format!("step-{step} results rejected: {e:#}"))),
            },
            TAG_ERROR => {
                let mut d = Dec::new(&payload);
                let msg = d
                    .str()
                    .map(str::to_string)
                    .unwrap_or_else(|_| "(unreadable error frame)".into());
                Err(StepFailure::Fatal(anyhow::anyhow!(
                    "worker {} failed on step {step}: {msg}",
                    slot.addr
                )))
            }
            other => Err(StepFailure::Dead(format!(
                "unexpected frame tag {other} while awaiting step-{step} results"
            ))),
        }
    }
}

fn decode_result_into(
    payload: &[u8],
    step: u64,
    range: &Range<usize>,
    addr: &str,
    out: &mut [ShardResult],
    filled: &mut [bool],
) -> Result<()> {
    let mut d = Dec::new(payload);
    let echo = d.u64()?;
    if echo != step {
        bail!("worker {addr} answered step {echo}, expected step {step} — protocol out of sync");
    }
    let count = d.u64()? as usize;
    if count != range.len() {
        bail!(
            "worker {addr} returned {count} shards, expected {} (assignment {range:?})",
            range.len()
        );
    }
    for _ in 0..count {
        let index = d.u64()? as usize;
        if !range.contains(&index) {
            bail!("worker {addr} returned shard {index} outside its assignment {range:?}");
        }
        if filled[index] {
            bail!("worker {addr} returned shard {index} twice");
        }
        let loss = d.f64()?;
        let slot = &mut out[index];
        slot.index = index;
        slot.loss = loss;
        d.f32s_into(&mut slot.grad)?;
        filled[index] = true;
    }
    Ok(())
}

impl ShardBackend for TcpClusterBackend {
    fn run_shards(
        &mut self,
        plan: &ShardPlan,
        job: &ShardJob,
        out: &mut Vec<ShardResult>,
    ) -> Result<()> {
        if job.op.name() != self.op_name {
            bail!(
                "cluster workers were configured for the {} operator (method {:?}) but this \
                 step runs {} — reconnect the cluster with the matching job spec",
                self.op_name,
                self.spec.method,
                job.op.name()
            );
        }
        if let Some(lambda) = job.op.lambda_g() {
            // compare bits: the workers rebuilt their operator from the
            // spec's exact f32
            if lambda.to_bits() != self.spec.lambda_g.to_bits() {
                bail!(
                    "this step's {} operator has lambda_g = {lambda} but the cluster was \
                     handshaken with {} — reconnect with the matching job spec",
                    job.op.name(),
                    self.spec.lambda_g
                );
            }
        }
        let n_params = job.mlp.n_params();
        if n_params != self.spec.n_params {
            bail!(
                "job has {n_params} parameters but the cluster was connected for {} — \
                 reconnect with the matching job spec",
                self.spec.n_params
            );
        }
        let n_tasks = plan.len();
        prepare_results(out, n_tasks);
        self.step += 1;
        let step = self.step;
        self.params_buf.resize(n_params, 0.0);
        job.mlp.pack_into(&mut self.params_buf);
        self.try_rejoin(step);
        // Supervised scatter/gather over a worklist of shard ranges.
        // Every requeue coincides with marking at least one worker dead
        // and rejoin only happens at step start, so the loop terminates:
        // either every shard fills or every worker is dead.  Because the
        // caller merges by shard index, *who* computed a shard — first
        // assignment or reassignment — never changes the reduced bits.
        let mut filled = vec![false; n_tasks];
        let mut todo: Vec<Range<usize>> = vec![0..n_tasks];
        while let Some(range) = todo.pop() {
            if range.is_empty() {
                continue;
            }
            let live: Vec<usize> =
                (0..self.slots.len()).filter(|&i| self.slots[i].stream.is_some()).collect();
            if live.is_empty() {
                return Err(self.all_dead_error(step));
            }
            let parts = split_range(&range, live.len());
            // Broadcast first: every worker starts computing while rank 0
            // is still writing to the next one.
            let mut sent: Vec<(usize, Range<usize>)> = Vec::new();
            for (&si, part) in live.iter().zip(&parts) {
                if part.is_empty() {
                    continue;
                }
                let d = self.spec.d;
                encode_step_into(
                    &mut self.step_buf,
                    step,
                    part,
                    &self.params_buf,
                    job.batch,
                    d,
                    plan.chunk_points,
                );
                let slot = &mut self.slots[si];
                match write_frame(
                    slot.stream.as_mut().expect("live slot"),
                    TAG_STEP,
                    &self.step_buf.buf,
                ) {
                    Ok(()) => sent.push((si, part.clone())),
                    Err(e) => {
                        self.mark_dead(si, step, &format!("sending step {step} (shards {part:?}): {e}"));
                        todo.push(part.clone());
                    }
                }
            }
            // Gather this round; merge ordering is the caller's
            // shard-index reduction, so gather order only affects
            // latency, never bits.
            for (si, part) in sent {
                match self.gather_one(si, step, &part, out, &mut filled) {
                    Ok(()) => {}
                    Err(StepFailure::Fatal(e)) => return Err(e),
                    Err(StepFailure::Dead(reason)) => {
                        // a half-decoded result may have filled a prefix
                        // of the part; recompute the whole part
                        for i in part.clone() {
                            filled[i] = false;
                        }
                        self.mark_dead(si, step, &reason);
                        todo.push(part);
                    }
                }
            }
        }
        if let Some(missing) = filled.iter().position(|f| !f) {
            bail!("no worker returned shard {missing} of step {step}");
        }
        Ok(())
    }

    fn parallelism(&self) -> usize {
        self.slots.len()
    }

    fn label(&self) -> String {
        format!("tcp-cluster(workers={})", self.slots.len())
    }

    fn take_events(&mut self) -> Vec<String> {
        std::mem::take(&mut self.events)
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

struct WorkerState {
    mlp: Mlp,
    problem: Box<dyn PdeProblem>,
    op: Box<dyn ResidualOp>,
    backend: super::shard::InProcessBackend,
    results: Vec<ShardResult>,
    n_params: usize,
    d: usize,
    // persistent per-step scratch (mirrors rank 0's recycled buffers:
    // at steady state a worker step performs no payload allocation)
    params: Vec<f32>,
    xs: Vec<f32>,
    probes: Vec<f32>,
    coeff: Vec<f32>,
    reply: Enc,
}

fn build_state(
    family: &str,
    method: &str,
    lambda_g: f32,
    d: usize,
    n_params: usize,
    threads: usize,
) -> Result<WorkerState> {
    let problem = problem_for(family, d)?;
    let op = residual_op_for(problem.as_ref(), method, lambda_g)?;
    let expect = Mlp::n_params_for(d);
    if n_params != expect {
        bail!(
            "coordinator expects {n_params} parameters but this worker's MLP at d={d} has \
             {expect} — mixed binary versions?"
        );
    }
    // Weights are overwritten by the first STEP's params; the init
    // values never matter, so a fixed throwaway seed is fine.
    let mlp = Mlp::init(d, &mut Xoshiro256pp::new(0));
    Ok(WorkerState {
        mlp,
        problem,
        op,
        backend: super::shard::InProcessBackend::new(threads),
        results: Vec::new(),
        n_params,
        d,
        params: Vec::new(),
        xs: Vec::new(),
        probes: Vec::new(),
        coeff: Vec::new(),
        reply: Enc::default(),
    })
}

/// The fixed-size prefix of a STEP frame; the four float arrays decode
/// straight into [`WorkerState`]'s persistent scratch buffers.
struct StepHeader {
    step: u64,
    lo: usize,
    hi: usize,
    n: usize,
    v: usize,
    chunk: usize,
    /// First batch point covered by the xs slice (= the range's span).
    base: usize,
}

fn decode_step_into(payload: &[u8], st: &mut WorkerState) -> Result<StepHeader> {
    let mut d = Dec::new(payload);
    let header = StepHeader {
        step: d.u64()?,
        lo: d.u64()? as usize,
        hi: d.u64()? as usize,
        n: d.u64()? as usize,
        v: d.u64()? as usize,
        chunk: d.u64()? as usize,
        base: d.u64()? as usize,
    };
    d.f32s_into(&mut st.params)?;
    d.f32s_into(&mut st.xs)?;
    d.f32s_into(&mut st.probes)?;
    d.f32s_into(&mut st.coeff)?;
    Ok(header)
}

/// Run one STEP, leaving the RESULT payload in `st.reply`.
fn run_step(st: &mut WorkerState, payload: &[u8]) -> Result<()> {
    let h = decode_step_into(payload, st)?;
    // The effective chunk is derived, not negotiated: both sides run
    // `plan_chunk_points` over the same job spec, so they agree exactly
    // when their `HTE_ARENA_KB` settings agree.  Recomputing it here
    // (instead of trusting the frame) keeps a misconfigured worker from
    // silently merging shards in a different order.
    let expect = plan_chunk_points(st.d, h.v, st.op.order(), st.n_params);
    if h.chunk != expect {
        bail!(
            "coordinator shards into {}-point chunks but this worker computes {expect} — \
             HTE_ARENA_KB must be set identically on every rank (or unset everywhere), \
             otherwise the bitwise shard plan would diverge",
            h.chunk
        );
    }
    if st.params.len() != st.n_params {
        bail!("step carries {} parameters, job spec said {}", st.params.len(), st.n_params);
    }
    if st.probes.len() != h.v * st.d {
        bail!("probe matrix has {} coords for v={} at d={}", st.probes.len(), h.v, st.d);
    }
    if st.coeff.len() != st.problem.n_coeff() {
        bail!(
            "step carries {} solution coefficients, the {} problem has {}",
            st.coeff.len(),
            st.problem.family(),
            st.problem.n_coeff()
        );
    }
    let n_shards = h.n.div_ceil(h.chunk);
    if h.lo > h.hi || h.hi > n_shards {
        bail!("shard range {}..{} outside the {n_shards}-shard plan", h.lo, h.hi);
    }
    // The coordinator ships only this assignment's xs slice; rebase the
    // shards onto it.  Same floats in the same order as the full-batch
    // plan, so the per-shard bits are unchanged.
    let (base, end) = point_span(h.lo, h.hi, h.n, h.chunk);
    if h.base != base {
        bail!("step's xs slice starts at point {} but the shard range implies {base}", h.base);
    }
    let n_local = end - base;
    if st.xs.len() != n_local * st.d {
        bail!("xs slice has {} coords for {n_local} points at d={}", st.xs.len(), st.d);
    }
    let local_plan = ShardPlan::with_chunk(n_local, h.chunk);
    if local_plan.len() != h.hi - h.lo {
        bail!(
            "xs slice of {n_local} points yields {} shards, assignment {}..{} expects {}",
            local_plan.len(),
            h.lo,
            h.hi,
            h.hi - h.lo
        );
    }
    st.mlp.unpack_into(&st.params);
    let batch =
        NativeBatch { xs: &st.xs, probes: &st.probes, coeff: &st.coeff, n: n_local, v: h.v };
    let job = ShardJob {
        mlp: &st.mlp,
        problem: st.problem.as_ref(),
        op: st.op.as_ref(),
        batch: &batch,
    };
    st.backend.run_shards(&local_plan, &job, &mut st.results)?;
    st.reply.buf.clear();
    st.reply.u64(h.step);
    st.reply.u64(st.results.len() as u64);
    for r in &st.results {
        // local shard j is global shard lo + j
        st.reply.u64((h.lo + r.index) as u64);
        st.reply.f64(r.loss);
        st.reply.f32s(&r.grad);
    }
    Ok(())
}

/// One coordinator session.  Returns `Ok(true)` to keep accepting
/// sessions, `Ok(false)` when fault injection says the worker dies.
fn handle_coordinator(
    mut stream: TcpStream,
    threads: usize,
    faults: &mut FaultState,
) -> Result<bool> {
    let dl = Deadlines::from_env();
    stream.set_nodelay(true).ok();
    // Handshake deadline until the session is established: a
    // connected-but-silent peer (port scan, half-open socket) is shed
    // in seconds and can never wedge the sequential accept loop.
    stream.set_read_timeout(Some(dl.handshake)).ok();
    stream.set_write_timeout(Some(dl.handshake)).ok();
    let Some((tag, payload)) = read_frame_or_eof(&mut stream)? else {
        return Ok(true); // connected and left without a word (port scan)
    };
    if tag != TAG_HELLO {
        let _ = send_error(&mut stream, "expected a hello frame");
        bail!("expected a hello frame, got tag {tag}");
    }
    let mut d = Dec::new(&payload);
    let version = d.u32()?;
    if version != PROTOCOL_VERSION {
        let msg = format!(
            "coordinator speaks shard protocol v{version}, this worker speaks \
             v{PROTOCOL_VERSION}"
        );
        let _ = send_error(&mut stream, &msg);
        bail!("{msg}");
    }
    let family = d.str()?.to_string();
    let method = d.str()?.to_string();
    let lambda_g = d.f32()?;
    let dim = d.u64()? as usize;
    let n_params = d.u64()? as usize;
    let mut st = match build_state(&family, &method, lambda_g, dim, n_params, threads) {
        Ok(st) => st,
        Err(e) => {
            // ship the full context chain — this is how `problem_for` /
            // `residual_op_for` supported-set errors reach the operator
            let _ = send_error(&mut stream, &format!("{e:#}"));
            return Err(e);
        }
    };
    let mut ack = Enc::default();
    ack.str(st.op.name());
    ack.u64(CHUNK_POINTS as u64);
    ack.u64(threads as u64);
    write_frame(&mut stream, TAG_HELLO_ACK, &ack.buf).context("sending hello ack")?;
    // Session established: switch to the (much longer) step deadline —
    // a coordinator may legitimately think for a while between steps.
    stream.set_read_timeout(Some(dl.step)).ok();
    stream.set_write_timeout(Some(dl.step)).ok();
    loop {
        let Some((tag, payload)) = read_frame_or_eof(&mut stream)? else {
            return Ok(true); // clean goodbye: coordinator closed
        };
        match tag {
            TAG_STEP => {
                // the coordinator step id is the frame's first word —
                // fault clauses key on it (and `stall_secs` sleeps
                // inside `on_step`, modelling a wedged worker)
                let step_id = payload
                    .get(..8)
                    .map(|b| u64::from_le_bytes(b.try_into().expect("8-byte slice")))
                    .unwrap_or(0);
                match faults.on_step(step_id) {
                    FaultAction::None => {}
                    FaultAction::Die => {
                        eprintln!(
                            "worker: fault injection: dying after {} served frame(s)",
                            faults.steps_served
                        );
                        if faults.plan.exit_process {
                            std::process::exit(3);
                        }
                        return Ok(false);
                    }
                    FaultAction::DropConn => {
                        eprintln!("worker: fault injection: dropping connection at step {step_id}");
                        return Ok(true);
                    }
                    FaultAction::CorruptFrame => {
                        eprintln!("worker: fault injection: corrupt frame at step {step_id}");
                        // garbage magic, RESULT tag, zero length: the
                        // coordinator must reject it and reassign
                        let mut head = [0u8; 13];
                        head[..4].copy_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
                        head[4] = TAG_RESULT;
                        let _ = stream.write_all(&head);
                        let _ = stream.flush();
                        return Ok(true);
                    }
                }
                match run_step(&mut st, &payload) {
                    Ok(()) => write_frame(&mut stream, TAG_RESULT, &st.reply.buf)
                        .context("sending results")?,
                    Err(e) => {
                        send_error(&mut stream, &format!("{e:#}")).context("sending error")?;
                        return Err(e);
                    }
                }
            }
            other => {
                let _ = send_error(&mut stream, &format!("unexpected frame tag {other}"));
                bail!("unexpected frame tag {other}");
            }
        }
    }
}

/// Bind a TCP listener with `SO_REUSEADDR` set, so a respawned process
/// can take over the port its predecessor died holding.  Rust's
/// `TcpListener::bind` never sets the flag, and when a worker or serve
/// replica dies its accepted connections sit in TIME_WAIT for ~60 s —
/// a plain rebind of the same port gets "address already in use" for
/// that whole minute, which is exactly the window a failover respawn
/// needs to land in.  Linux only lets a `SO_REUSEADDR` bind fold
/// TIME_WAIT entries whose own socket carried the flag, so the *first*
/// incarnation must bind through here too (accepted connections
/// inherit it from the listener); that is why every listening CLI verb
/// (`worker`, `serve`, `router`) uses this instead of a plain bind.
/// Non-IPv4 listen addresses fall back to the plain bind.
pub fn bind_reuse(listen: &str) -> Result<TcpListener> {
    let addr = listen
        .to_socket_addrs()
        .with_context(|| format!("resolving listen address {listen}"))?
        .next()
        .with_context(|| format!("listen address {listen} resolves to nothing"))?;
    match addr {
        SocketAddr::V4(v4) => {
            reuseaddr::bind_v4(v4).with_context(|| format!("binding {listen} with SO_REUSEADDR"))
        }
        other => TcpListener::bind(other).with_context(|| format!("binding {listen}")),
    }
}

/// The raw-socket dance behind [`bind_reuse`]: libc `socket` /
/// `setsockopt(SO_REUSEADDR)` / `bind` / `listen`, handed to std via
/// `FromRawFd`.  Spelled out against the C ABI (same idiom as the
/// SIGHUP latch in `runtime::serve`) because the crate deliberately
/// has no libc dependency.
#[cfg(unix)]
mod reuseaddr {
    use std::net::{SocketAddrV4, TcpListener};
    use std::os::unix::io::FromRawFd;

    use anyhow::{bail, Result};

    // Linux/BSD values, identical on x86_64 and aarch64.
    const AF_INET: i32 = 2;
    const SOCK_STREAM: i32 = 1;
    const SOL_SOCKET: i32 = 1;
    const SO_REUSEADDR: i32 = 2;
    const BACKLOG: i32 = 128;

    /// `struct sockaddr_in`: family in host order, port and address in
    /// network byte order.
    #[repr(C)]
    struct SockaddrIn {
        sin_family: u16,
        sin_port: u16,
        sin_addr: u32,
        sin_zero: [u8; 8],
    }

    extern "C" {
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn setsockopt(fd: i32, level: i32, name: i32, value: *const i32, len: u32) -> i32;
        fn bind(fd: i32, addr: *const SockaddrIn, len: u32) -> i32;
        fn listen(fd: i32, backlog: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub(super) fn bind_v4(v4: SocketAddrV4) -> Result<TcpListener> {
        let sa = SockaddrIn {
            sin_family: AF_INET as u16,
            sin_port: v4.port().to_be(),
            // network order = the octets laid out in memory as-is
            sin_addr: u32::from_ne_bytes(v4.ip().octets()),
            sin_zero: [0u8; 8],
        };
        unsafe {
            let fd = socket(AF_INET, SOCK_STREAM, 0);
            if fd < 0 {
                bail!("socket(): {}", std::io::Error::last_os_error());
            }
            let one: i32 = 1;
            if setsockopt(fd, SOL_SOCKET, SO_REUSEADDR, &one, std::mem::size_of::<i32>() as u32)
                != 0
            {
                let e = std::io::Error::last_os_error();
                let _ = close(fd);
                bail!("setsockopt(SO_REUSEADDR): {e}");
            }
            if bind(fd, &sa, std::mem::size_of::<SockaddrIn>() as u32) != 0 {
                let e = std::io::Error::last_os_error();
                let _ = close(fd);
                bail!("bind(): {e}");
            }
            if listen(fd, BACKLOG) != 0 {
                let e = std::io::Error::last_os_error();
                let _ = close(fd);
                bail!("listen(): {e}");
            }
            Ok(TcpListener::from_raw_fd(fd))
        }
    }
}

#[cfg(not(unix))]
mod reuseaddr {
    use std::net::{SocketAddrV4, TcpListener};

    use anyhow::Result;

    pub(super) fn bind_v4(v4: SocketAddrV4) -> Result<TcpListener> {
        Ok(TcpListener::bind(v4)?)
    }
}

/// Blocking worker loop behind `hte-pinn worker --listen`: accept
/// coordinators one at a time, forever.  Each coordinator session runs
/// its shards with `threads` in-process worker threads (the thread
/// count never changes the bits — see [`ShardPlan`]).  Fault injection
/// comes from `HTE_FAULT` (rank-gated by `HTE_WORKER_RANK`), and a
/// `die_after_steps` death exits the process — a real crash.
pub fn serve(listener: TcpListener, threads: usize) -> Result<()> {
    let mut plan = FaultPlan::from_env()?;
    plan.exit_process = true;
    serve_conns_with_faults(listener, threads, None, plan)
}

/// Like [`serve`], stopping after `max_conns` coordinator sessions
/// when given — tests run loopback workers on in-process threads this
/// way — and injecting no faults.
pub fn serve_conns(listener: TcpListener, threads: usize, max_conns: Option<usize>) -> Result<()> {
    serve_conns_with_faults(listener, threads, max_conns, FaultPlan::default())
}

/// The full worker accept loop: sequential coordinator sessions sharing
/// one [`FaultState`] (so `die_after_steps` counts frames across
/// sessions).  Session-level errors are logged and the worker keeps
/// accepting; an injected death stops the loop (and, for real CLI
/// workers, exits the process from inside the session handler).
pub fn serve_conns_with_faults(
    listener: TcpListener,
    threads: usize,
    max_conns: Option<usize>,
    plan: FaultPlan,
) -> Result<()> {
    let mut faults = FaultState::new(plan);
    let mut served = 0usize;
    for stream in listener.incoming() {
        let stream = stream.context("accepting a coordinator connection")?;
        let peer = match stream.peer_addr() {
            Ok(addr) => addr.to_string(),
            Err(_) => "?".into(),
        };
        match handle_coordinator(stream, threads, &mut faults) {
            Ok(true) => {}
            Ok(false) => return Ok(()), // injected death: stop serving
            Err(e) => eprintln!("worker: session with {peer} ended with an error: {e:#}"),
        }
        served += 1;
        if let Some(max) = max_conns {
            if served >= max {
                break;
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Local worker processes (`train --workers N`)
// ---------------------------------------------------------------------------

/// `N` `hte-pinn worker` child processes on loopback ports, spawned for
/// `train --workers N` and killed on drop.  Each child prints
/// `listening on <addr>` once bound (port 0 = kernel-assigned), which
/// is how the parent learns the addresses without a port race.
pub struct LocalWorkerPool {
    children: Vec<Child>,
    /// Kept open so a worker writing to stdout never hits a closed pipe.
    _stdouts: Vec<BufReader<ChildStdout>>,
    pub addrs: Vec<String>,
    /// Remembered for [`LocalWorkerPool::respawn_addr`].
    program: PathBuf,
    threads: usize,
}

/// Spawn one worker child on `listen`, wait for its printed address.
/// `rank` lands in `HTE_WORKER_RANK` so an inherited `HTE_FAULT` spec
/// can target a single worker of the fleet; respawns clear `HTE_FAULT`
/// (a restarted worker should not re-crash on schedule).
fn spawn_worker_child(
    program: &Path,
    rank: usize,
    threads: usize,
    listen: &str,
    fault: Option<&str>,
    clear_fault_env: bool,
) -> Result<(Child, BufReader<ChildStdout>, String)> {
    let mut cmd = Command::new(program);
    cmd.args(["worker", "--listen", listen, "--threads"])
        .arg(threads.to_string())
        .env("HTE_WORKER_RANK", rank.to_string())
        .stdout(Stdio::piped());
    if let Some(spec) = fault {
        cmd.args(["--fault", spec]);
    }
    if clear_fault_env {
        cmd.env_remove("HTE_FAULT");
    }
    let mut child =
        cmd.spawn().with_context(|| format!("spawning local worker {rank} from {program:?}"))?;
    let stdout = child.stdout.take().expect("stdout was piped");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    reader
        .read_line(&mut line)
        .with_context(|| format!("reading local worker {rank}'s listen address"))?;
    let Some(addr) = line.trim().strip_prefix("listening on ") else {
        let _ = child.kill();
        let _ = child.wait();
        bail!("local worker {rank} printed {line:?} instead of its listen address");
    };
    Ok((child, reader, addr.to_string()))
}

impl LocalWorkerPool {
    /// Spawn from the currently running binary (the `train` path).
    pub fn spawn(n: usize, threads: usize) -> Result<Self> {
        let exe = std::env::current_exe().context("locating the hte-pinn binary")?;
        Self::spawn_with(&exe, n, threads)
    }

    /// Spawn from an explicit binary path (tests use
    /// `env!("CARGO_BIN_EXE_hte-pinn")`).
    pub fn spawn_with(program: &Path, n: usize, threads: usize) -> Result<Self> {
        Self::spawn_with_faults(program, n, threads, &[])
    }

    /// [`LocalWorkerPool::spawn_with`] handing child `i` the fault spec
    /// `faults[i]` via `worker --fault` (the chaos tests).
    pub fn spawn_with_faults(
        program: &Path,
        n: usize,
        threads: usize,
        faults: &[Option<&str>],
    ) -> Result<Self> {
        if n == 0 {
            bail!("--workers needs at least 1 worker process");
        }
        let mut pool = LocalWorkerPool {
            children: Vec::new(),
            _stdouts: Vec::new(),
            addrs: Vec::new(),
            program: program.to_path_buf(),
            threads,
        };
        for i in 0..n {
            let fault = faults.get(i).copied().flatten();
            let (child, reader, addr) =
                spawn_worker_child(program, i, threads, "127.0.0.1:0", fault, false)?;
            pool.addrs.push(addr);
            pool.children.push(child);
            pool._stdouts.push(reader);
        }
        Ok(pool)
    }

    /// Kill worker `i` (the chaos tests: its shards must be reassigned,
    /// never hang the run).
    pub fn kill_one(&mut self, i: usize) {
        if let Some(child) = self.children.get_mut(i) {
            let _ = child.kill();
            let _ = child.wait();
        }
    }

    /// Respawn the child that owned `addr` if — and only if — it has
    /// exited: `Ok(true)` when a fresh worker is listening on the same
    /// address again, `Ok(false)` when the address isn't ours or the
    /// child is still alive (a connection loss is not always a crash).
    /// This is the [`RespawnHook`] `train --workers N` installs.
    pub fn respawn_addr(&mut self, addr: &str) -> Result<bool> {
        let Some(i) = self.addrs.iter().position(|a| a == addr) else {
            return Ok(false);
        };
        match self.children[i].try_wait() {
            Ok(None) => return Ok(false), // still running
            Ok(Some(_)) | Err(_) => {}
        }
        // rebind the exact same address: SO_REUSEADDR (std's default on
        // listeners) lets the fresh child take over the port
        let (child, reader, new_addr) =
            spawn_worker_child(&self.program, i, self.threads, addr, None, true)?;
        if new_addr != addr {
            bail!("respawned worker bound {new_addr}, expected {addr}");
        }
        self.children[i] = child;
        self._stdouts[i] = reader;
        Ok(true)
    }
}

impl Drop for LocalWorkerPool {
    fn drop(&mut self) {
        for child in &mut self.children {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativeTrainer;
    use crate::estimators::Estimator;
    use crate::nn::{default_residual_op, NativeEngine};
    use crate::pde::{Domain, DomainSampler};
    use crate::rng::{fill_rademacher, Normal};

    /// Loopback worker on an in-process thread: real TCP, no child
    /// process.  Serves `conns` coordinator sessions then exits.
    fn spawn_test_worker(threads: usize, conns: usize) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let addr = listener.local_addr().expect("local addr").to_string();
        std::thread::spawn(move || {
            let _ = serve_conns(listener, threads, Some(conns));
        });
        addr
    }

    /// [`spawn_test_worker`] with a fault-injection spec (in-process, so
    /// an injected death stops the serve loop instead of exiting).
    fn spawn_faulty_worker(threads: usize, conns: usize, spec: &str) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let addr = listener.local_addr().expect("local addr").to_string();
        let plan = FaultPlan::parse(spec).expect("fault spec");
        std::thread::spawn(move || {
            let _ = serve_conns_with_faults(listener, threads, Some(conns), plan);
        });
        addr
    }

    /// Chaos-test recovery knobs: short deadlines, no connect retries,
    /// rejoin attempted at every step boundary.
    fn fast_opts() -> ClusterOpts {
        ClusterOpts {
            deadlines: Deadlines {
                connect: Duration::from_secs(2),
                handshake: Duration::from_secs(2),
                step: Duration::from_secs(5),
            },
            max_worker_retries: 0,
            rejoin_interval: Duration::from_secs(0),
        }
    }

    /// A reference in-process trainer and a cluster trainer over
    /// `addrs`, identically configured.
    fn chaos_pair(
        cfg: &TrainConfig,
        addrs: &[String],
        opts: ClusterOpts,
    ) -> (NativeTrainer, NativeTrainer) {
        let local = NativeTrainer::with_threads(cfg.clone(), 9, 3).expect("local trainer");
        let backend = TcpClusterBackend::connect_with(addrs, JobSpec::from_config(cfg), opts)
            .expect("connect cluster");
        let remote =
            NativeTrainer::with_backend(cfg.clone(), 9, Box::new(backend)).expect("remote trainer");
        (local, remote)
    }

    /// Step both trainers `steps` times asserting per-step loss bits,
    /// then the full packed params|m|v|t state, are identical — the
    /// recovery paths must change latency, never bits.
    fn assert_bitwise_match(local: &mut NativeTrainer, remote: &mut NativeTrainer, steps: usize) {
        for step in 0..steps {
            local.step().expect("local step");
            remote.step().expect("remote step");
            assert_eq!(
                local.last_loss.to_bits(),
                remote.last_loss.to_bits(),
                "loss diverged at step {step}"
            );
        }
        let (a, b) = (local.state_host(), remote.state_host());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "packed params|m|v|t state diverged");
        }
    }

    fn train_config(family: &str, method: &str, d: usize, epochs: usize) -> TrainConfig {
        let estimator =
            if family == "bihar" { Estimator::HteGaussian } else { Estimator::HteRademacher };
        TrainConfig {
            family: family.into(),
            method: method.into(),
            estimator,
            d,
            v: 4,
            epochs,
            lr0: 2e-3,
            seed: 5,
            lambda_g: 10.0,
            log_every: usize::MAX,
        }
    }

    /// The xs-slice spans of a step's assignments tile the batch
    /// exactly: contiguous, disjoint, complete — for any worker count.
    #[test]
    fn shard_point_spans_tile_the_batch() {
        for chunk in [1usize, 2, 3, CHUNK_POINTS] {
            for n in [1usize, 4, 5, 11, 16, 17] {
                let plan = ShardPlan::with_chunk(n, chunk);
                for workers in 1..=4 {
                    let mut next = 0usize;
                    for r in plan.assignment(workers) {
                        let (base, end) = point_span(r.start, r.end, n, chunk);
                        if r.is_empty() {
                            assert_eq!(base, end, "empty assignment must get an empty span");
                        } else {
                            assert_eq!(base, next, "chunk={chunk} n={n} workers={workers}: span gap");
                            assert!(end > base);
                            next = end;
                        }
                    }
                    assert_eq!(
                        next, n,
                        "chunk={chunk} n={n} workers={workers}: spans must cover the batch"
                    );
                }
            }
        }
    }

    /// The worker-side rebasing invariant the bitwise guarantee rests
    /// on: a local plan over an assignment's xs slice has exactly the
    /// global slice's shards, shifted by the span base.
    #[test]
    fn shard_local_rebased_plan_matches_global_slice() {
        for chunk in [2usize, CHUNK_POINTS] {
            for n in [1usize, 5, 11, 16] {
                let plan = ShardPlan::with_chunk(n, chunk);
                for workers in 1..=3 {
                    for r in plan.assignment(workers) {
                        let (base, end) = point_span(r.start, r.end, n, chunk);
                        let local = ShardPlan::with_chunk(end - base, chunk);
                        assert_eq!(local.len(), r.len());
                        let global = &plan.shards()[r.clone()];
                        for (j, (ls, gs)) in local.shards().iter().zip(global).enumerate() {
                            assert_eq!(ls.index, j, "local indices start at 0");
                            assert_eq!(base + ls.start, gs.start, "rebased start must agree");
                            assert_eq!(ls.nc, gs.nc, "shard sizes must agree");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn wire_roundtrip_is_bit_exact() {
        let mut e = Enc::default();
        e.u32(7);
        e.str("sg2");
        e.f32(f32::from_bits(0x7f80_0001)); // a signaling NaN survives
        e.f64(-0.0);
        e.f32s(&[1.5, -2.25, f32::NEG_INFINITY]);
        let mut d = Dec::new(&e.buf);
        assert_eq!(d.u32().unwrap(), 7);
        assert_eq!(d.str().unwrap(), "sg2");
        assert_eq!(d.f32().unwrap().to_bits(), 0x7f80_0001);
        assert_eq!(d.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        let xs = d.f32s().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2], f32::NEG_INFINITY);
        // over-reading is a clean error, not a panic
        assert!(d.u64().is_err());
    }

    /// The acceptance gate: engine-level loss + full gradient over the
    /// TCP cluster backend are bitwise identical to the in-process
    /// backend, for every residual family and multiple worker counts.
    #[test]
    fn shard_cluster_loopback_matches_in_process_bitwise() {
        for (family, method, domain, gaussian) in [
            ("sg2", "probe", Domain::UnitBall, false),
            ("bihar", "probe4", Domain::Annulus, true),
            ("ac2", "hte", Domain::UnitBall, false),
        ] {
            let (d, n, v) = (4usize, 11usize, 4usize);
            let mut rng = Xoshiro256pp::new(61);
            let mlp = Mlp::init(d, &mut rng);
            let problem = problem_for(family, d).unwrap();
            let mut sampler = DomainSampler::new(domain, d, rng.fork(1));
            let xs = sampler.batch(n);
            let mut probes = vec![0.0f32; v * d];
            if gaussian {
                Normal::new().fill_f32(&mut rng, &mut probes);
            } else {
                fill_rademacher(&mut rng, &mut probes);
            }
            let mut coeff = vec![0.0f32; problem.n_coeff()];
            Normal::new().fill_f32(&mut rng, &mut coeff);
            let batch = NativeBatch { xs: &xs, probes: &probes, coeff: &coeff, n, v };
            let op = default_residual_op(problem.as_ref());

            let mut ref_engine = NativeEngine::new(3);
            let mut ref_grad = Vec::new();
            let ref_loss = ref_engine
                .loss_and_grad_with(&mlp, problem.as_ref(), op, &batch, &mut ref_grad)
                .unwrap();

            let mut cfg = train_config(family, method, d, 1);
            cfg.v = v;
            for workers in [1usize, 2, 3] {
                let addrs: Vec<String> = (0..workers).map(|_| spawn_test_worker(2, 1)).collect();
                let backend =
                    TcpClusterBackend::connect(&addrs, JobSpec::from_config(&cfg)).unwrap();
                let mut engine = NativeEngine::with_backend(Box::new(backend));
                assert_eq!(engine.threads(), workers);
                let mut grad = Vec::new();
                let loss = engine
                    .loss_and_grad_with(&mlp, problem.as_ref(), op, &batch, &mut grad)
                    .unwrap();
                assert_eq!(
                    loss.to_bits(),
                    ref_loss.to_bits(),
                    "{family}: loss differs over tcp with {workers} workers"
                );
                assert_eq!(grad.len(), ref_grad.len());
                for (a, b) in grad.iter().zip(&ref_grad) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{family}: gradient differs over tcp with {workers} workers"
                    );
                }
            }
        }
    }

    /// Whole-trainer parity: N steps of Adam over a 2-worker loopback
    /// cluster leave byte-identical parameters vs in-process threads.
    #[test]
    fn shard_cluster_trainer_steps_match_in_process_bitwise() {
        let cfg = train_config("sg2", "probe", 5, 8);
        let mut local = NativeTrainer::with_threads(cfg.clone(), 9, 3).unwrap();
        let addrs: Vec<String> = (0..2).map(|_| spawn_test_worker(2, 1)).collect();
        let backend = TcpClusterBackend::connect(&addrs, JobSpec::from_config(&cfg)).unwrap();
        let mut remote = NativeTrainer::with_backend(cfg, 9, Box::new(backend)).unwrap();
        assert!(remote.executor().contains("tcp-cluster"));
        for _ in 0..8 {
            local.step().unwrap();
            remote.step().unwrap();
        }
        assert_eq!(local.last_loss.to_bits(), remote.last_loss.to_bits());
        let (a, b) = (local.mlp.pack(), remote.mlp.pack());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "parameters diverged over the cluster");
        }
    }

    /// A worker that dies mid-run no longer aborts training: its shards
    /// are reassigned to the survivors within the same step and the
    /// result is bitwise identical to the in-process run.
    #[test]
    fn shard_cluster_dead_worker_shards_reassigned_bitwise() {
        // this "worker" acks the handshake, then drops the connection
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let Ok(Some((tag, _payload))) = read_frame_or_eof(&mut stream) else { return };
            assert_eq!(tag, TAG_HELLO);
            let mut ack = Enc::default();
            ack.str("trace");
            ack.u64(CHUNK_POINTS as u64);
            ack.u64(1);
            let _ = write_frame(&mut stream, TAG_HELLO_ACK, &ack.buf);
            // connection drops here — the coordinator's first STEP read
            // EOFs and the shards move to the healthy worker
        });
        let healthy = spawn_test_worker(1, 1);
        let cfg = train_config("sg2", "probe", 4, 2);
        let mut local = NativeTrainer::with_threads(cfg.clone(), 9, 3).unwrap();
        let backend = TcpClusterBackend::connect_with(
            &[addr.clone(), healthy],
            JobSpec::from_config(&cfg),
            fast_opts(),
        )
        .unwrap();
        let mut remote = NativeTrainer::with_backend(cfg, 9, Box::new(backend)).unwrap();
        assert_bitwise_match(&mut local, &mut remote, 2);
        assert!(remote.recoveries >= 1, "the death must be recorded as a recovery");
        let log = remote.recovery_log.join("\n");
        assert!(log.contains(&addr), "recovery log must name the dead worker: {log}");
    }

    /// Tentpole acceptance: a 3-worker run where the middle worker is
    /// killed mid-run (injected crash after 2 served steps) completes
    /// with loss and state bits identical to the in-process run.
    #[test]
    fn shard_chaos_killed_worker_shards_reassigned_bitwise() {
        let cfg = train_config("sg2", "probe", 5, 6);
        let dying = spawn_faulty_worker(2, 1, "die_after_steps=2");
        let addrs = vec![spawn_test_worker(2, 1), dying.clone(), spawn_test_worker(2, 1)];
        let (mut local, mut remote) = chaos_pair(&cfg, &addrs, fast_opts());
        assert_bitwise_match(&mut local, &mut remote, 6);
        assert!(remote.recoveries >= 1, "the kill must be recorded as a recovery");
        let log = remote.recovery_log.join("\n");
        assert!(log.contains(&dying), "recovery log must name the dead worker: {log}");
        assert!(log.contains("reassigned"), "{log}");
    }

    /// A wedged worker (stalls 30 s on step 2 with the socket open) is
    /// caught by the 1 s step deadline and its shards reassigned — the
    /// blanket-timeout design would have blocked for 10 minutes.
    #[test]
    fn shard_chaos_stalled_worker_times_out_and_reassigns_bitwise() {
        let cfg = train_config("sg2", "probe", 4, 3);
        let stalled = spawn_faulty_worker(1, 1, "stall_secs=30@2");
        let addrs = vec![stalled.clone(), spawn_test_worker(2, 1)];
        let mut opts = fast_opts();
        opts.deadlines.step = Duration::from_secs(1);
        // never re-dial the wedged worker inside this test
        opts.rejoin_interval = Duration::from_secs(3600);
        let (mut local, mut remote) = chaos_pair(&cfg, &addrs, opts);
        assert_bitwise_match(&mut local, &mut remote, 3);
        assert!(remote.recoveries >= 1);
        let log = remote.recovery_log.join("\n");
        assert!(log.contains(&stalled), "recovery log must name the stalled worker: {log}");
    }

    /// A worker that drops its connection mid-run rejoins via a fresh
    /// handshake at the next step boundary — and the bits never change.
    #[test]
    fn shard_chaos_dropped_conn_rejoins_bitwise() {
        let cfg = train_config("sg2", "probe", 4, 4);
        let flaky = spawn_faulty_worker(1, 2, "drop_conn@2");
        let addrs = vec![flaky.clone(), spawn_test_worker(2, 1)];
        let (mut local, mut remote) = chaos_pair(&cfg, &addrs, fast_opts());
        assert_bitwise_match(&mut local, &mut remote, 4);
        let log = remote.recovery_log.join("\n");
        assert!(log.contains("dead"), "the drop must be recorded: {log}");
        assert!(log.contains("rejoined"), "the worker must rejoin after its drop: {log}");
    }

    /// A corrupt frame (garbage magic) is rejected, the worker marked
    /// dead and its shards recomputed by the survivor; the corrupt bytes
    /// can never leak into the merge.
    #[test]
    fn shard_chaos_corrupt_frame_is_rejected_and_reassigned_bitwise() {
        let cfg = train_config("sg2", "probe", 4, 3);
        let corrupt = spawn_faulty_worker(1, 2, "corrupt_frame@1");
        let addrs = vec![corrupt.clone(), spawn_test_worker(2, 1)];
        let (mut local, mut remote) = chaos_pair(&cfg, &addrs, fast_opts());
        assert_bitwise_match(&mut local, &mut remote, 3);
        let log = remote.recovery_log.join("\n");
        assert!(log.contains(&corrupt) && log.contains("dead"), "{log}");
        assert!(log.contains("rejoined"), "the corrupt worker rejoins cleanly: {log}");
    }

    /// Losing every worker is the one unsurvivable failure: it must
    /// fail fast with a diagnostic naming each worker and why it died.
    #[test]
    fn shard_chaos_all_workers_dead_fails_fast_with_named_workers() {
        let cfg = train_config("sg2", "probe", 4, 2);
        let a = spawn_faulty_worker(1, 1, "die_after_steps=1");
        let b = spawn_faulty_worker(1, 1, "die_after_steps=1");
        let backend = TcpClusterBackend::connect_with(
            &[a.clone(), b.clone()],
            JobSpec::from_config(&cfg),
            fast_opts(),
        )
        .unwrap();
        let mut trainer = NativeTrainer::with_backend(cfg, 9, Box::new(backend)).unwrap();
        trainer.step().expect("step 1: both workers alive");
        let err = format!("{:#}", trainer.step().unwrap_err());
        assert!(err.contains("all 2 cluster workers are dead"), "{err}");
        assert!(err.contains(&a) && err.contains(&b), "error must name every worker: {err}");
    }

    /// The rejoin primitive at the protocol level: one worker serves a
    /// session, the coordinator disconnects, and a brand-new coordinator
    /// re-handshakes the same worker and trains bitwise-correctly.
    #[test]
    fn shard_worker_serves_sequential_coordinator_sessions() {
        let cfg = train_config("sg2", "probe", 4, 2);
        let addr = spawn_test_worker(2, 2);
        // session 1: one step, then goodbye (drop closes the socket)
        {
            let backend =
                TcpClusterBackend::connect(&[addr.clone()], JobSpec::from_config(&cfg)).unwrap();
            let mut first = NativeTrainer::with_backend(cfg.clone(), 9, Box::new(backend)).unwrap();
            first.step().unwrap();
        }
        // session 2: a fresh coordinator re-handshakes the same worker
        let mut local = NativeTrainer::with_threads(cfg.clone(), 9, 3).unwrap();
        let backend = TcpClusterBackend::connect(&[addr], JobSpec::from_config(&cfg)).unwrap();
        let mut remote = NativeTrainer::with_backend(cfg, 9, Box::new(backend)).unwrap();
        assert_bitwise_match(&mut local, &mut remote, 2);
    }

    #[test]
    fn cluster_deadlines_resolve_explicit_legacy_and_defaults() {
        let d = Deadlines::resolve([None, None, None], None);
        assert_eq!(d.connect, Duration::from_secs(10));
        assert_eq!(d.handshake, Duration::from_secs(10));
        assert_eq!(d.step, Duration::from_secs(600));
        // the legacy blanket timeout backfills any phase not explicitly
        // set; explicit per-phase values win over it
        let d = Deadlines::resolve([None, Some(7), None], Some(42));
        assert_eq!(d.connect, Duration::from_secs(42));
        assert_eq!(d.handshake, Duration::from_secs(7));
        assert_eq!(d.step, Duration::from_secs(42));
        // zero clamps to 1 s (a zero socket timeout means "block forever")
        let d = Deadlines::resolve([Some(0), None, None], None);
        assert_eq!(d.connect, Duration::from_secs(1));
    }

    #[test]
    fn cluster_backoff_is_bounded_and_grows() {
        let salt = addr_salt("127.0.0.1:9999");
        let d0 = backoff_delay(0, salt);
        assert!(d0 >= Duration::from_millis(100) && d0 <= Duration::from_millis(125), "{d0:?}");
        let d3 = backoff_delay(3, salt);
        assert!(d3 >= Duration::from_millis(800) && d3 <= Duration::from_millis(1000), "{d3:?}");
        // capped: base tops out at 5 s + 25% jitter, for any attempt
        for attempt in 0..20 {
            assert!(backoff_delay(attempt, salt) <= Duration::from_millis(6_250));
        }
        // deterministic per (addr, attempt)
        assert_eq!(backoff_delay(2, salt), backoff_delay(2, salt));
    }

    /// An operator whose λ differs from the handshaken job spec must
    /// fail loudly, not silently train with the workers' λ.
    #[test]
    fn shard_cluster_rejects_mismatched_lambda() {
        use crate::nn::GpinnResidual;
        let addr = spawn_test_worker(1, 1);
        let mut cfg = train_config("sg2", "gpinn", 4, 1);
        cfg.lambda_g = 10.0;
        let backend = TcpClusterBackend::connect(&[addr], JobSpec::from_config(&cfg)).unwrap();
        let mut engine = NativeEngine::with_backend(Box::new(backend));

        let (d, n, v) = (4usize, 5usize, 2usize);
        let mut rng = Xoshiro256pp::new(71);
        let mlp = Mlp::init(d, &mut rng);
        let problem = problem_for("sg2", d).unwrap();
        let mut sampler = DomainSampler::new(Domain::UnitBall, d, rng.fork(1));
        let xs = sampler.batch(n);
        let mut probes = vec![0.0f32; v * d];
        fill_rademacher(&mut rng, &mut probes);
        let mut coeff = vec![0.0f32; problem.n_coeff()];
        Normal::new().fill_f32(&mut rng, &mut coeff);
        let batch = NativeBatch { xs: &xs, probes: &probes, coeff: &coeff, n, v };

        let wrong = GpinnResidual { lambda: 5.0 };
        let mut grad = Vec::new();
        let err = engine
            .loss_and_grad_with(&mlp, problem.as_ref(), &wrong, &batch, &mut grad)
            .unwrap_err()
            .to_string();
        assert!(err.contains("lambda_g"), "{err}");
        // the matching operator goes through
        let right = GpinnResidual { lambda: 10.0 };
        engine.loss_and_grad_with(&mlp, problem.as_ref(), &right, &batch, &mut grad).unwrap();
    }

    /// A bad job spec is rejected during the handshake with the
    /// supported-set error text from the worker's own validation.
    #[test]
    fn shard_cluster_handshake_rejects_unknown_family_and_method() {
        let addr = spawn_test_worker(1, 1);
        let mut cfg = train_config("sg2", "probe", 4, 1);
        cfg.family = "sg9".into();
        let err = TcpClusterBackend::connect(&[addr], JobSpec::from_config(&cfg))
            .unwrap_err()
            .to_string();
        assert!(err.contains("sg9"), "{err}");
        assert!(err.contains("supported"), "{err}");

        let addr = spawn_test_worker(1, 1);
        let mut cfg = train_config("sg2", "probe", 4, 1);
        cfg.method = "probe4".into();
        let err = TcpClusterBackend::connect(&[addr], JobSpec::from_config(&cfg))
            .unwrap_err()
            .to_string();
        assert!(err.contains("probe4"), "{err}");
    }

    /// A respawned listener takes over a port whose previous owner died
    /// holding live connections.  Closing the accepted side first is an
    /// active close, which parks the connection in TIME_WAIT on the
    /// server's (port-owning) side — the state that makes a plain
    /// rebind fail with "address already in use" for ~60 s.  Binding
    /// through [`bind_reuse`] both times must succeed immediately.
    #[test]
    fn cluster_bind_reuse_takes_over_a_port_left_in_time_wait() {
        let first = bind_reuse("127.0.0.1:0").expect("first bind");
        let port = first.local_addr().unwrap().port();
        let addr = format!("127.0.0.1:{port}");
        let client = TcpStream::connect(&addr).expect("dialing the first listener");
        let (accepted, _) = first.accept().expect("accepting");
        drop(accepted); // server closes first -> server-side TIME_WAIT
        drop(client);
        drop(first);
        std::thread::sleep(Duration::from_millis(100)); // let the FINs trade
        let second = bind_reuse(&addr).expect("rebinding the dead process's port");
        let probe = TcpStream::connect(&addr).expect("dialing the respawned listener");
        drop(probe);
        drop(second);
    }
}
