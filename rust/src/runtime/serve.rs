//! `hte-pinn serve`: a batched, observable inference tier for trained
//! PINN surrogates (DESIGN.md §11).
//!
//! A serve process loads one checkpoint, reconstructs the constrained
//! model (`factor(x) * mlp(x)`, the same [`Mlp::forward_constrained`]
//! the trainer evaluates), and answers `[n, d]` query batches over the
//! cluster's framed wire protocol — same `[magic][tag][len]` framing,
//! same HELLO handshake, three new tags (`QUERY`/`ANSWER`/`STATS`).
//!
//! Design constraints, in order:
//!
//! 1. **Bitwise determinism.**  A served answer is the bits a local
//!    [`Mlp::forward_constrained`] call would have produced for the
//!    same checkpoint and the same point — regardless of batch size,
//!    microbatch boundary, evaluator-thread count, or SIMD dispatch
//!    level.  The whole chain is row-independent: the matmul kernels
//!    accumulate each output row in a fixed k-order (`tensor::matmul`),
//!    so [`Mlp::forward_batch`] equals per-point `forward` to the bit,
//!    and microbatch splits only re-group rows.
//! 2. **No hangs, bounded memory.**  The request queue is bounded;
//!    when it is full the server *answers* — an [`TAG_ANSWER`] frame
//!    with a rejected status and a diagnostic string, never a silent
//!    drop or an unbounded buffer.  Every socket phase carries the
//!    per-phase [`Deadlines`] (PR 6): a connected-but-silent client is
//!    shed on the handshake deadline, a wedged one on the step
//!    deadline, and neither can stall other connections (one handler
//!    thread per connection).
//! 3. **Observable.**  Per-request latency, throughput, queue depth
//!    and rejection counts are kept server-side and exported two ways:
//!    a [`TAG_STATS`] request answers with a JSON snapshot, and
//!    `--metrics FILE` streams the same snapshots as JSONL through the
//!    training tier's [`MetricsLogger`].
//!
//! Protocol (after the shared HELLO/HELLO_ACK handshake — the client's
//! HELLO may leave family/method empty as a wildcard; `d`/`n_params`
//! are always cross-checked):
//!
//! ```text
//! client                                server
//!   HELLO {version, family, method,
//!          lambda_g, d, n_params}    ->
//!                                    <- HELLO_ACK {"serve", family, d,
//!                                                  n_params, max_batch}
//!                                       (or ERROR {message})
//!   pipelined:
//!   QUERY {id, n, xs[n*d]}          ->
//!                                    <- ANSWER {id, status=0, u[n] f64}
//!                                       (or ANSWER {id, status=1, why}
//!                                        on saturation / oversize)
//!   STATS {}                        ->
//!                                    <- STATS {json snapshot}
//!   (connection drop = goodbye; malformed frames are fatal: ERROR)
//! ```
//!
//! Answers to pipelined queries may arrive out of submission order
//! (the evaluator pool is concurrent) — clients match on `id`.

use std::collections::{HashMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::checkpoint;
use crate::coordinator::{problem_for, MetricsLogger};
use crate::autodiff::{plan_enabled, Tape};
use crate::nn::{forward_batch_planned, ForwardScratch, Mlp};
use crate::pde::PdeProblem;
use crate::rng::Xoshiro256pp;

use super::cluster::{
    connect_worker, encode_hello, read_frame, read_frame_or_eof, send_error, write_frame, Deadlines,
    Dec, Enc, JobSpec, PROTOCOL_VERSION, TAG_ANSWER, TAG_ERROR, TAG_HELLO, TAG_HELLO_ACK,
    TAG_QUERY, TAG_STATS,
};

/// [`TAG_ANSWER`] status word: the batch was evaluated, `n` f64 values
/// follow.
const ANSWER_OK: u32 = 0;
/// [`TAG_ANSWER`] status word: the batch was *not* evaluated (queue
/// saturated or batch oversized); a diagnostic string follows.  The
/// connection stays usable — rejection is backpressure, not an error.
const ANSWER_REJECTED: u32 = 1;

/// Latency ring capacity: percentiles are computed over the most
/// recent `LAT_CAP` answered queries (bounded memory at any uptime).
const LAT_CAP: usize = 1 << 16;

// ---------------------------------------------------------------------------
// The servable model
// ---------------------------------------------------------------------------

/// A trained constrained model, rebuilt from a checkpoint: the MLP
/// weights plus the problem family's hard-constraint factor.  `Send +
/// Sync` (the problem trait requires it), so one instance is shared by
/// every evaluator thread behind an `Arc`.
pub struct ServeModel {
    pub mlp: Mlp,
    problem: Box<dyn PdeProblem>,
    /// The job spec served clients are validated against (family,
    /// method, d, n_params — same struct the training handshake uses).
    pub spec: JobSpec,
    /// Training step the checkpoint was saved at (surfaced in logs).
    pub step: usize,
}

/// Per-evaluator-thread scratch for [`ServeModel::eval_batch`]: the
/// forward ping-pong buffers plus factor/value staging, so the steady
/// state of a serving thread allocates nothing.
#[derive(Default)]
pub struct EvalScratch {
    fwd: ForwardScratch,
    factors: Vec<f64>,
    vals: Vec<f64>,
    /// Raw (unconstrained) forward values for the planned path.
    raw: Vec<f32>,
    /// Recorder/replayer for forward-only plans (one plan per batch
    /// shape, cached per evaluator thread).
    tape: Tape,
}

impl ServeModel {
    /// Build a servable model around explicit weights (tests, benches).
    pub fn new(mlp: Mlp, family: &str, method: &str) -> Result<Self> {
        let problem = problem_for(family, mlp.d)?;
        let spec = JobSpec {
            family: family.to_string(),
            method: method.to_string(),
            lambda_g: 0.0,
            d: mlp.d,
            n_params: mlp.n_params(),
        };
        Ok(Self { mlp, problem, spec, step: 0 })
    }

    /// Rebuild the constrained model from a training checkpoint: the
    /// state payload is the optimizer layout `params|m|v|t` (3n+1
    /// floats), and serving needs only the leading `n` parameters.
    pub fn from_checkpoint(path: impl AsRef<Path>) -> Result<Self> {
        let (meta, state) = checkpoint::load(&path)
            .with_context(|| format!("loading checkpoint {:?}", path.as_ref()))?;
        let n = meta.model.n_params;
        if state.len() != 3 * n + 1 {
            bail!(
                "checkpoint state holds {} floats but the optimizer layout for {} parameters \
                 is {} (params|m|v|t) — not a training checkpoint this binary can serve",
                state.len(),
                n,
                3 * n + 1
            );
        }
        let mut mlp = Mlp::init(meta.model.d, &mut Xoshiro256pp::new(meta.config.seed));
        mlp.unpack_into(&state[..n]);
        let problem = problem_for(&meta.model.family, meta.model.d)
            .context("rebuilding the checkpoint's problem family")?;
        Ok(Self {
            mlp,
            problem,
            spec: JobSpec::from_config(&meta.config),
            step: meta.step,
        })
    }

    pub fn d(&self) -> usize {
        self.mlp.d
    }

    /// Evaluate `[n, d]` points, *appending* `n` constrained values to
    /// `out`.  Bitwise equal per point to
    /// [`Mlp::forward_constrained`] — the factor is computed by the
    /// same `PdeProblem::factor` the trainer's evaluator calls, and the
    /// batched forward is row-independent (see the module docs).
    pub fn eval_batch(&self, xs: &[f32], n: usize, out: &mut Vec<f64>, scratch: &mut EvalScratch) {
        assert_eq!(xs.len(), n * self.mlp.d, "xs must be [n, d] row-major");
        scratch.factors.clear();
        scratch.factors.extend(xs.chunks_exact(self.mlp.d).map(|x| self.problem.factor(x)));
        if plan_enabled() {
            // Forward-only plan replay: bitwise the eager batched
            // forward (DESIGN.md §12), amortizing graph construction
            // across the steady stream of same-shape microbatches.
            forward_batch_planned(&mut scratch.tape, &self.mlp, xs, n, &mut scratch.raw);
            out.extend(
                scratch.raw.iter().zip(&scratch.factors).map(|(&u, &f)| f * u as f64),
            );
            return;
        }
        self.mlp
            .forward_constrained_batch(xs, n, &scratch.factors, &mut scratch.vals, &mut scratch.fwd);
        out.extend_from_slice(&scratch.vals);
    }

    /// Allocating convenience around [`ServeModel::eval_batch`] (the
    /// loadgen verifier and tests compute expected bits through this).
    pub fn eval(&self, xs: &[f32]) -> Vec<f64> {
        let n = xs.len() / self.mlp.d;
        let mut out = Vec::with_capacity(n);
        self.eval_batch(xs, n, &mut out, &mut EvalScratch::default());
        out
    }
}

// ---------------------------------------------------------------------------
// Server knobs
// ---------------------------------------------------------------------------

/// Serving knobs.  Defaults come from the environment-resolved
/// [`Deadlines`] and conservative capacity constants; tests override
/// everything explicitly.
pub struct ServeOpts {
    pub deadlines: Deadlines,
    /// Evaluator threads draining the shared queue.
    pub threads: usize,
    /// Points per SIMD matmul call: a large request is split into
    /// `microbatch`-point slices so one huge query cannot hold an
    /// evaluator's working set beyond cache (splits never change bits —
    /// rows are independent).
    pub microbatch: usize,
    /// Bounded queue capacity, in *requests*.  A full queue rejects
    /// gracefully (status-1 ANSWER), it never buffers unboundedly.
    pub queue_cap: usize,
    /// Largest accepted `n` per query; larger batches are rejected
    /// with a named diagnostic (the cap is advertised in the ACK).
    pub max_batch: usize,
    /// How often the metrics reporter snapshots to the JSONL stream.
    pub metrics_interval: Duration,
    /// Test hook: hold each evaluated request this long *before*
    /// evaluating, making saturation deterministic in tests.  `None`
    /// (always, outside tests) evaluates immediately.
    pub eval_delay: Option<Duration>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            deadlines: Deadlines::from_env(),
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            microbatch: 256,
            queue_cap: 64,
            max_batch: 16_384,
            metrics_interval: Duration::from_secs(1),
            eval_delay: None,
        }
    }
}

// ---------------------------------------------------------------------------
// Bounded queue + per-connection shared write side
// ---------------------------------------------------------------------------

/// The write half of one client connection, shared between its handler
/// thread (rejections, stats) and every evaluator thread (answers).
/// Frames are written whole under the lock, so pipelined answers never
/// interleave mid-frame.
struct ConnShared {
    stream: Mutex<TcpStream>,
    /// Cleared on the first write error; later answers for this
    /// connection are dropped instead of erroring every evaluator.
    alive: AtomicBool,
}

impl ConnShared {
    fn send(&self, tag: u8, payload: &[u8]) {
        if !self.alive.load(Ordering::Acquire) {
            return;
        }
        let mut stream = self.stream.lock().expect("conn lock poisoned");
        if write_frame(&mut stream, tag, payload).is_err() {
            self.alive.store(false, Ordering::Release);
        }
    }
}

/// One accepted query waiting for an evaluator.
struct Job {
    id: u64,
    n: usize,
    xs: Vec<f32>,
    accepted: Instant,
    conn: Arc<ConnShared>,
}

struct QueueInner {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Bounded MPMC queue: handlers push (failing fast when full — that
/// failure *is* the backpressure signal), evaluators block on pop.
struct Queue {
    inner: Mutex<QueueInner>,
    avail: Condvar,
    cap: usize,
}

impl Queue {
    fn new(cap: usize) -> Self {
        Queue {
            inner: Mutex::new(QueueInner { jobs: VecDeque::new(), shutdown: false }),
            avail: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Non-blocking: `Err(job)` hands the job back when the queue is
    /// full (the handler turns it into a status-1 ANSWER).
    fn push(&self, job: Job) -> std::result::Result<(), Job> {
        let mut inner = self.inner.lock().expect("queue lock poisoned");
        if inner.shutdown || inner.jobs.len() >= self.cap {
            return Err(job);
        }
        inner.jobs.push_back(job);
        drop(inner);
        self.avail.notify_one();
        Ok(())
    }

    /// Blocking: `None` once shut down *and* drained.
    fn pop(&self) -> Option<Job> {
        let mut inner = self.inner.lock().expect("queue lock poisoned");
        loop {
            if let Some(job) = inner.jobs.pop_front() {
                return Some(job);
            }
            if inner.shutdown {
                return None;
            }
            inner = self.avail.wait(inner).expect("queue lock poisoned");
        }
    }

    fn depth(&self) -> usize {
        self.inner.lock().expect("queue lock poisoned").jobs.len()
    }

    fn shutdown(&self) {
        self.inner.lock().expect("queue lock poisoned").shutdown = true;
        self.avail.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Observability
// ---------------------------------------------------------------------------

struct StatsInner {
    /// Answered queries (status 0).
    queries: u64,
    /// Points across answered queries.
    points: u64,
    /// Status-1 rejections (saturation + oversize).
    rejected: u64,
    /// Ring of the most recent `LAT_CAP` accept→answer latencies, µs.
    lat_us: Vec<u64>,
}

/// Shared server-side counters; snapshots come out as
/// [`ServeSnapshot`].
pub struct ServeStats {
    inner: Mutex<StatsInner>,
    started: Instant,
}

impl ServeStats {
    fn new() -> Self {
        ServeStats {
            inner: Mutex::new(StatsInner {
                queries: 0,
                points: 0,
                rejected: 0,
                lat_us: Vec::new(),
            }),
            started: Instant::now(),
        }
    }

    fn record_answer(&self, n: usize, latency: Duration) {
        let us = latency.as_micros().min(u64::MAX as u128) as u64;
        let mut st = self.inner.lock().expect("stats lock poisoned");
        if st.lat_us.len() < LAT_CAP {
            st.lat_us.push(us);
        } else {
            let at = (st.queries % LAT_CAP as u64) as usize;
            st.lat_us[at] = us;
        }
        st.queries += 1;
        st.points += n as u64;
    }

    fn record_rejection(&self) {
        self.inner.lock().expect("stats lock poisoned").rejected += 1;
    }

    fn snapshot(&self, queue_depth: usize) -> ServeSnapshot {
        let st = self.inner.lock().expect("stats lock poisoned");
        let elapsed_s = self.started.elapsed().as_secs_f64().max(1e-9);
        let (queries, points, rejected) = (st.queries, st.points, st.rejected);
        let mut lat = st.lat_us.clone();
        drop(st);
        lat.sort_unstable();
        ServeSnapshot {
            elapsed_s,
            queries,
            points,
            rejected,
            qps: queries as f64 / elapsed_s,
            p50_ms: percentile_ms(&lat, 0.50),
            p95_ms: percentile_ms(&lat, 0.95),
            p99_ms: percentile_ms(&lat, 0.99),
            queue_depth,
        }
    }
}

/// Nearest-rank percentile over an ascending µs slice, in ms (0 when
/// empty — a fresh server has no latency story to tell yet).
fn percentile_ms(sorted_us: &[u64], q: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() - 1) as f64 * q).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)] as f64 / 1_000.0
}

/// One observability snapshot: the [`TAG_STATS`] reply body and the
/// `--metrics` JSONL line share this schema.
#[derive(Clone, Debug)]
pub struct ServeSnapshot {
    pub elapsed_s: f64,
    pub queries: u64,
    pub points: u64,
    pub rejected: u64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub queue_depth: usize,
}

impl ServeSnapshot {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"elapsed_s\":{:.3},\"queries\":{},\"points\":{},\"rejected\":{},\
             \"qps\":{:.3},\"p50_ms\":{:.3},\"p95_ms\":{:.3},\"p99_ms\":{:.3},\
             \"queue_depth\":{}}}",
            self.elapsed_s,
            self.queries,
            self.points,
            self.rejected,
            self.qps,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.queue_depth
        )
    }
}

// ---------------------------------------------------------------------------
// The serve loop
// ---------------------------------------------------------------------------

fn encode_answer_ok(id: u64, values: &[f64]) -> Vec<u8> {
    let mut e = Enc::default();
    e.u64(id);
    e.u32(ANSWER_OK);
    e.f64s(values);
    e.buf
}

fn encode_answer_rejected(id: u64, why: &str) -> Vec<u8> {
    let mut e = Enc::default();
    e.u64(id);
    e.u32(ANSWER_REJECTED);
    e.str(why);
    e.buf
}

/// One evaluator thread: drain the queue until shutdown, microbatching
/// each request through the SIMD forward and answering on the
/// request's own connection.
fn evaluator_loop(
    model: &ServeModel,
    queue: &Queue,
    stats: &ServeStats,
    microbatch: usize,
    eval_delay: Option<Duration>,
) {
    let d = model.mlp.d;
    let mb = microbatch.max(1);
    let mut scratch = EvalScratch::default();
    let mut out: Vec<f64> = Vec::new();
    while let Some(job) = queue.pop() {
        if let Some(delay) = eval_delay {
            std::thread::sleep(delay);
        }
        out.clear();
        let mut off = 0;
        while off < job.n {
            let take = (job.n - off).min(mb);
            model.eval_batch(&job.xs[off * d..(off + take) * d], take, &mut out, &mut scratch);
            off += take;
        }
        // count before sending: a client that has *seen* an answer can
        // never observe a stats snapshot that hasn't counted it yet
        // (latency therefore excludes the answer write — negligible)
        stats.record_answer(job.n, job.accepted.elapsed());
        job.conn.send(TAG_ANSWER, &encode_answer_ok(job.id, &out));
    }
}

/// Validate a serve client's HELLO against the loaded model.  Family
/// and method act as wildcards when empty — a generic client can dial
/// any surrogate — but `d` and `n_params` are always cross-checked (a
/// dimension mismatch would mis-stride every query payload).
fn check_hello(payload: &[u8], spec: &JobSpec) -> Result<()> {
    let mut dec = Dec::new(payload);
    let version = dec.u32()?;
    if version != PROTOCOL_VERSION {
        bail!("client speaks protocol v{version}, this server speaks v{PROTOCOL_VERSION}");
    }
    let family = dec.str()?;
    let method = dec.str()?;
    let _lambda_g = dec.f32()?; // training-only knob, ignored at inference
    let d = dec.u64()? as usize;
    let n_params = dec.u64()? as usize;
    if d != spec.d {
        bail!("client expects d={d} but this server loaded a d={} checkpoint", spec.d);
    }
    if n_params != spec.n_params {
        bail!(
            "client expects {n_params} parameters but the loaded checkpoint has {} — \
             mixed binary versions?",
            spec.n_params
        );
    }
    if !family.is_empty() && family != spec.family {
        bail!(
            "client expects problem family {family} but this server loaded a {} checkpoint",
            spec.family
        );
    }
    if !method.is_empty() && method != spec.method {
        bail!(
            "client expects method {method} but this server loaded a {} checkpoint",
            spec.method
        );
    }
    Ok(())
}

/// One client session: handshake, then accept pipelined QUERY/STATS
/// frames until the client hangs up.  Protocol violations (bad magic,
/// absurd lengths, mis-sized payloads) are fatal to the *connection*;
/// saturation and oversize are answered gracefully on it.
fn handle_client(
    mut stream: TcpStream,
    model: &ServeModel,
    queue: &Queue,
    stats: &ServeStats,
    opts_max_batch: usize,
    dl: &Deadlines,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(dl.handshake)).ok();
    stream.set_write_timeout(Some(dl.handshake)).ok();
    let Some((tag, payload)) = read_frame_or_eof(&mut stream)? else {
        return Ok(()); // connected and left without a word (port scan)
    };
    if tag != TAG_HELLO {
        let _ = send_error(&mut stream, "expected a hello frame");
        bail!("expected a hello frame, got tag {tag}");
    }
    if let Err(e) = check_hello(&payload, &model.spec) {
        let _ = send_error(&mut stream, &format!("{e:#}"));
        return Err(e);
    }
    let mut ack = Enc::default();
    ack.str("serve");
    ack.str(&model.spec.family);
    ack.u64(model.spec.d as u64);
    ack.u64(model.spec.n_params as u64);
    ack.u64(opts_max_batch as u64);
    write_frame(&mut stream, TAG_HELLO_ACK, &ack.buf).context("sending serve ack")?;
    // Session established: queries run under the (longer) step deadline.
    stream.set_read_timeout(Some(dl.step)).ok();
    stream.set_write_timeout(Some(dl.step)).ok();
    let conn = Arc::new(ConnShared {
        stream: Mutex::new(stream.try_clone().context("cloning the answer stream")?),
        alive: AtomicBool::new(true),
    });
    let d = model.mlp.d;
    loop {
        let Some((tag, payload)) = read_frame_or_eof(&mut stream)? else {
            return Ok(()); // clean goodbye
        };
        match tag {
            TAG_QUERY => {
                let accepted = Instant::now();
                let mut dec = Dec::new(&payload);
                let id = dec.u64()?;
                let n = dec.u64()? as usize;
                let mut xs = Vec::new();
                dec.f32s_into(&mut xs)?;
                if xs.len() != n * d {
                    // fatal: write through the shared side so the error
                    // frame can't interleave with an in-flight answer
                    let msg = format!(
                        "query {id} claims n={n} points at d={d} but ships {} coords",
                        xs.len()
                    );
                    let mut e = Enc::default();
                    e.str(&msg);
                    conn.send(TAG_ERROR, &e.buf);
                    bail!("{msg}");
                }
                if n > opts_max_batch {
                    stats.record_rejection();
                    conn.send(
                        TAG_ANSWER,
                        &encode_answer_rejected(
                            id,
                            &format!(
                                "batch of {n} points exceeds this server's max_batch \
                                 {opts_max_batch} — split the request"
                            ),
                        ),
                    );
                    continue;
                }
                let job = Job { id, n, xs, accepted, conn: Arc::clone(&conn) };
                if let Err(job) = queue.push(job) {
                    stats.record_rejection();
                    conn.send(
                        TAG_ANSWER,
                        &encode_answer_rejected(
                            job.id,
                            &format!(
                                "server saturated: the {}-request queue is full — \
                                 back off and retry",
                                queue.cap
                            ),
                        ),
                    );
                }
            }
            TAG_STATS => {
                let mut e = Enc::default();
                e.str(&stats.snapshot(queue.depth()).to_json());
                conn.send(TAG_STATS, &e.buf);
            }
            other => {
                let mut e = Enc::default();
                e.str(&format!("unexpected frame tag {other}"));
                conn.send(TAG_ERROR, &e.buf);
                bail!("unexpected frame tag {other}");
            }
        }
        if !conn.alive.load(Ordering::Acquire) {
            bail!("client write side failed — dropping the session");
        }
    }
}

/// The serve accept loop.  Spawns `opts.threads` evaluator threads
/// over one bounded queue, one handler thread per accepted connection,
/// and (when `metrics` is given) a snapshot reporter on
/// `opts.metrics_interval`.
///
/// With `max_conns: Some(k)` the loop accepts exactly `k` connections,
/// joins their handlers, drains the queue, stops the evaluators and
/// flushes a final metrics snapshot before returning — the shape every
/// test and bench uses.  `None` serves forever (the CLI path).
pub fn serve_queries(
    listener: TcpListener,
    model: Arc<ServeModel>,
    opts: ServeOpts,
    max_conns: Option<usize>,
    metrics: Option<MetricsLogger>,
) -> Result<()> {
    let queue = Arc::new(Queue::new(opts.queue_cap));
    let stats = Arc::new(ServeStats::new());
    let stop = Arc::new(AtomicBool::new(false));

    let mut evaluators = Vec::new();
    for _ in 0..opts.threads.max(1) {
        let model = Arc::clone(&model);
        let queue = Arc::clone(&queue);
        let stats = Arc::clone(&stats);
        let (mb, delay) = (opts.microbatch, opts.eval_delay);
        evaluators.push(std::thread::spawn(move || {
            evaluator_loop(&model, &queue, &stats, mb, delay);
        }));
    }

    let reporter = metrics.map(|mut logger| {
        let stats = Arc::clone(&stats);
        let queue = Arc::clone(&queue);
        let stop = Arc::clone(&stop);
        let interval = opts.metrics_interval;
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                std::thread::sleep(interval);
                let _ = logger.log_line(&stats.snapshot(queue.depth()).to_json());
            }
            // final snapshot so even sub-interval runs leave a line
            let _ = logger.log_line(&stats.snapshot(queue.depth()).to_json());
            let _ = logger.finish();
        })
    });

    let mut handlers = Vec::new();
    let mut served = 0usize;
    for stream in listener.incoming() {
        let stream = stream.context("accepting a serve connection")?;
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
        let model = Arc::clone(&model);
        let queue = Arc::clone(&queue);
        let stats = Arc::clone(&stats);
        let (max_batch, dl) = (opts.max_batch, opts.deadlines);
        let handle = std::thread::spawn(move || {
            if let Err(e) =
                handle_client(stream, &model, &queue, &stats, max_batch, &dl)
            {
                eprintln!("serve: session with {peer} ended with an error: {e:#}");
            }
        });
        if max_conns.is_some() {
            handlers.push(handle);
        }
        served += 1;
        if let Some(max) = max_conns {
            if served >= max {
                break;
            }
        }
    }
    for h in handlers {
        let _ = h.join();
    }
    queue.shutdown();
    for h in evaluators {
        let _ = h.join();
    }
    stop.store(true, Ordering::Release);
    if let Some(r) = reporter {
        let _ = r.join();
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// What one query came back as.
#[derive(Clone, Debug, PartialEq)]
pub enum QueryReply {
    /// Evaluated: one f64 per point, bit-for-bit the local forward.
    Answer(Vec<f64>),
    /// Gracefully rejected (saturation / oversize) with the server's
    /// diagnostic; the connection remains usable.
    Rejected(String),
}

/// A serve-protocol client: dial, handshake, then `query` (one
/// outstanding) or `send_query`/`read_reply` (pipelined, match on id).
pub struct ServeClient {
    stream: TcpStream,
    pub d: usize,
    /// Largest batch the server advertised in its ACK.
    pub max_batch: usize,
    next_id: u64,
}

impl ServeClient {
    /// Connect and handshake.  The HELLO carries empty family/method —
    /// the generic-client wildcard — plus `d` and the architecture's
    /// parameter count, which the server cross-checks.
    pub fn connect(addr: &str, d: usize, dl: &Deadlines) -> Result<Self> {
        let spec = JobSpec {
            family: String::new(),
            method: String::new(),
            lambda_g: 0.0,
            d,
            n_params: Mlp::n_params_for(d),
        };
        let mut stream = connect_worker(addr, dl.connect)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(dl.handshake)).ok();
        stream.set_write_timeout(Some(dl.handshake)).ok();
        write_frame(&mut stream, TAG_HELLO, &encode_hello(&spec))
            .context("sending the serve hello")?;
        let (tag, payload) = read_frame(&mut stream).context("waiting for the serve ack")?;
        match tag {
            TAG_HELLO_ACK => {
                let mut dec = Dec::new(&payload);
                let tier = dec.str()?;
                if tier != "serve" {
                    bail!(
                        "endpoint {addr} acked as {tier:?}, not a serve tier — \
                         dialed a training worker?"
                    );
                }
                let _family = dec.str()?;
                let got_d = dec.u64()? as usize;
                let _n_params = dec.u64()?;
                let max_batch = dec.u64()? as usize;
                if got_d != d {
                    bail!("server acked d={got_d}, expected {d}");
                }
                stream.set_read_timeout(Some(dl.step)).ok();
                stream.set_write_timeout(Some(dl.step)).ok();
                Ok(ServeClient { stream, d, max_batch, next_id: 0 })
            }
            TAG_ERROR => {
                let mut dec = Dec::new(&payload);
                let msg = dec.str().unwrap_or("(unreadable error frame)");
                bail!("server {addr} rejected the handshake: {msg}")
            }
            other => bail!("server {addr} sent unexpected frame tag {other} during handshake"),
        }
    }

    /// Fire one `[n, d]` query without waiting; returns its id.
    /// Pipelined replies may come back in any order.
    pub fn send_query(&mut self, xs: &[f32]) -> Result<u64> {
        assert_eq!(xs.len() % self.d, 0, "xs must be [n, d] row-major");
        let id = self.next_id;
        self.next_id += 1;
        let mut e = Enc::default();
        e.u64(id);
        e.u64((xs.len() / self.d) as u64);
        e.f32s(xs);
        write_frame(&mut self.stream, TAG_QUERY, &e.buf).context("sending a query")?;
        Ok(id)
    }

    /// Read one ANSWER frame (any pipelined id).
    pub fn read_reply(&mut self) -> Result<(u64, QueryReply)> {
        let (tag, payload) = read_frame(&mut self.stream).context("waiting for an answer")?;
        match tag {
            TAG_ANSWER => Self::decode_answer(&payload),
            TAG_ERROR => {
                let mut dec = Dec::new(&payload);
                let msg = dec.str().unwrap_or("(unreadable error frame)");
                bail!("server error: {msg}")
            }
            other => bail!("expected an answer frame, got tag {other}"),
        }
    }

    fn decode_answer(payload: &[u8]) -> Result<(u64, QueryReply)> {
        let mut dec = Dec::new(payload);
        let id = dec.u64()?;
        let status = dec.u32()?;
        match status {
            ANSWER_OK => {
                let mut values = Vec::new();
                dec.f64s_into(&mut values)?;
                Ok((id, QueryReply::Answer(values)))
            }
            ANSWER_REJECTED => Ok((id, QueryReply::Rejected(dec.str()?.to_string()))),
            other => bail!("answer {id} carries unknown status {other}"),
        }
    }

    /// One blocking round trip (no other queries outstanding).
    pub fn query(&mut self, xs: &[f32]) -> Result<QueryReply> {
        let id = self.send_query(xs)?;
        let (got, reply) = self.read_reply()?;
        if got != id {
            bail!("answer id {got} does not match query id {id} — pipelined? use read_reply");
        }
        Ok(reply)
    }

    /// Fetch the server's observability snapshot (JSON).  Call with no
    /// queries outstanding — the reply shares the stream.
    pub fn stats(&mut self) -> Result<String> {
        write_frame(&mut self.stream, TAG_STATS, &[]).context("sending a stats request")?;
        let (tag, payload) = read_frame(&mut self.stream).context("waiting for stats")?;
        if tag != TAG_STATS {
            bail!("expected a stats frame, got tag {tag}");
        }
        let mut dec = Dec::new(&payload);
        Ok(dec.str()?.to_string())
    }
}

// ---------------------------------------------------------------------------
// Load generator
// ---------------------------------------------------------------------------

pub use crate::config::Arrival;

/// Load-generator shape: `conns` connections, `requests` total queries
/// of `batch` points each, either closed-loop (one outstanding per
/// connection — measures capacity) or open-loop at `rate` queries/sec
/// total (paced arrivals regardless of completions — measures behavior
/// under offered load, the model that actually saturates the queue).
pub struct LoadgenOpts {
    pub addr: String,
    pub d: usize,
    pub arrival: Arrival,
    /// Open-loop only: total offered queries/sec across connections.
    pub rate: f64,
    pub conns: usize,
    /// Points per query.
    pub batch: usize,
    /// Total queries across all connections.
    pub requests: usize,
    pub seed: u64,
    pub deadlines: Deadlines,
}

/// What a loadgen run measured.  `bitwise_ok` is the determinism gate:
/// every answered query was compared bit-for-bit against a local
/// [`ServeModel::eval`] when a verify model was supplied.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub sent: usize,
    pub answered: usize,
    pub rejected: usize,
    pub wall_s: f64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Answered queries that were bitwise-verified (0 without a model).
    pub bitwise_checked: usize,
    pub bitwise_ok: bool,
}

impl LoadgenReport {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"sent\":{},\"answered\":{},\"rejected\":{},\"wall_s\":{:.3},\
             \"qps\":{:.3},\"p50_ms\":{:.3},\"p95_ms\":{:.3},\"p99_ms\":{:.3},\
             \"bitwise_checked\":{},\"bitwise_ok\":{}}}",
            self.sent,
            self.answered,
            self.rejected,
            self.wall_s,
            self.qps,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.bitwise_checked,
            self.bitwise_ok
        )
    }
}

/// What one connection's worth of load measured.
#[derive(Default)]
struct ConnTally {
    sent: usize,
    answered: usize,
    rejected: usize,
    lat_us: Vec<u64>,
    bitwise_checked: usize,
    bitwise_bad: usize,
}

fn random_batch(rng: &mut Xoshiro256pp, n: usize, d: usize) -> Vec<f32> {
    (0..n * d).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
}

/// Bit-compare an answer against the local model; returns true when
/// every value matches exactly.
fn bits_match(expected: &[f64], got: &[f64]) -> bool {
    expected.len() == got.len()
        && expected.iter().zip(got).all(|(a, b)| a.to_bits() == b.to_bits())
}

fn closed_loop_conn(
    opts: &LoadgenOpts,
    conn_idx: usize,
    n_requests: usize,
    verify: Option<&ServeModel>,
) -> Result<ConnTally> {
    let mut client = ServeClient::connect(&opts.addr, opts.d, &opts.deadlines)?;
    let mut rng = Xoshiro256pp::new(opts.seed ^ (0x9E37 + conn_idx as u64));
    let mut tally = ConnTally::default();
    for _ in 0..n_requests {
        let xs = random_batch(&mut rng, opts.batch, opts.d);
        let t0 = Instant::now();
        let reply = client.query(&xs)?;
        tally.sent += 1;
        match reply {
            QueryReply::Answer(values) => {
                tally.lat_us.push(t0.elapsed().as_micros() as u64);
                tally.answered += 1;
                if let Some(model) = verify {
                    tally.bitwise_checked += 1;
                    if !bits_match(&model.eval(&xs), &values) {
                        tally.bitwise_bad += 1;
                    }
                }
            }
            QueryReply::Rejected(_) => tally.rejected += 1,
        }
    }
    Ok(tally)
}

fn open_loop_conn(
    opts: &LoadgenOpts,
    conn_idx: usize,
    n_requests: usize,
    verify: Option<&ServeModel>,
) -> Result<ConnTally> {
    let mut client = ServeClient::connect(&opts.addr, opts.d, &opts.deadlines)?;
    let mut reader = client.stream.try_clone().context("cloning the reply stream")?;
    let mut rng = Xoshiro256pp::new(opts.seed ^ (0x9E37 + conn_idx as u64));
    // id -> (sent-at, expected bits when verifying)
    let pending: Mutex<HashMap<u64, (Instant, Option<Vec<f64>>)>> = Mutex::new(HashMap::new());
    let sent = AtomicUsize::new(0);
    let sender_done = AtomicBool::new(false);
    let per_conn_rate = (opts.rate / opts.conns.max(1) as f64).max(1e-9);
    let interval = Duration::from_secs_f64(1.0 / per_conn_rate);
    let mut tally = ConnTally::default();
    std::thread::scope(|scope| -> Result<()> {
        let reader_thread = scope.spawn(|| -> Result<ConnTally> {
            let mut t = ConnTally::default();
            loop {
                if sender_done.load(Ordering::Acquire)
                    && t.answered + t.rejected >= sent.load(Ordering::Acquire)
                {
                    return Ok(t);
                }
                let (tag, payload) =
                    read_frame(&mut reader).context("waiting for an open-loop answer")?;
                if tag == TAG_STATS {
                    continue; // the sender's end-of-run nudge: re-check above
                }
                if tag != TAG_ANSWER {
                    bail!("expected an answer frame, got tag {tag}");
                }
                let (id, reply) = ServeClient::decode_answer(&payload)?;
                let Some((t0, expected)) = pending.lock().expect("pending lock").remove(&id)
                else {
                    bail!("answer for unknown query id {id}");
                };
                match reply {
                    QueryReply::Answer(values) => {
                        t.lat_us.push(t0.elapsed().as_micros() as u64);
                        t.answered += 1;
                        if let Some(expected) = expected {
                            t.bitwise_checked += 1;
                            if !bits_match(&expected, &values) {
                                t.bitwise_bad += 1;
                            }
                        }
                    }
                    QueryReply::Rejected(_) => t.rejected += 1,
                }
            }
        });
        let start = Instant::now();
        for i in 0..n_requests {
            let due = start + interval.mul_f64(i as f64);
            if let Some(wait) = due.checked_duration_since(Instant::now()) {
                std::thread::sleep(wait);
            }
            let xs = random_batch(&mut rng, opts.batch, opts.d);
            let expected = verify.map(|m| m.eval(&xs));
            // register before sending: the reader may win the race
            let id = client.next_id;
            pending.lock().expect("pending lock").insert(id, (Instant::now(), expected));
            match client.send_query(&xs) {
                Ok(sent_id) => debug_assert_eq!(sent_id, id),
                Err(e) => {
                    pending.lock().expect("pending lock").remove(&id);
                    sender_done.store(true, Ordering::Release);
                    return Err(e);
                }
            }
            sent.fetch_add(1, Ordering::Release);
        }
        sender_done.store(true, Ordering::Release);
        // Wake the reader if it blocked on read *before* seeing the
        // done flag: the stats reply is one guaranteed frame after the
        // flag flips, closing the check-then-block race.
        let _ = write_frame(&mut client.stream, TAG_STATS, &[]);
        tally = reader_thread.join().expect("open-loop reader panicked")?;
        tally.sent = sent.load(Ordering::Acquire);
        Ok(())
    })?;
    Ok(tally)
}

/// Run the load generator against a serve endpoint.  With
/// `verify: Some(model)`, every answered query is compared bit-for-bit
/// against the local forward — the report's `bitwise_ok` is the serve
/// tier's determinism gate.
pub fn run_loadgen(opts: &LoadgenOpts, verify: Option<&ServeModel>) -> Result<LoadgenReport> {
    if opts.conns == 0 || opts.requests == 0 {
        bail!("loadgen needs at least one connection and one request");
    }
    let start = Instant::now();
    let tallies: Vec<Result<ConnTally>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..opts.conns {
            // split `requests` across connections, remainder to the low ranks
            let n_req = opts.requests / opts.conns + usize::from(c < opts.requests % opts.conns);
            handles.push(scope.spawn(move || match opts.arrival {
                Arrival::Closed => closed_loop_conn(opts, c, n_req, verify),
                Arrival::Open => open_loop_conn(opts, c, n_req, verify),
            }));
        }
        handles.into_iter().map(|h| h.join().expect("loadgen connection panicked")).collect()
    });
    let wall_s = start.elapsed().as_secs_f64().max(1e-9);
    let mut total = ConnTally::default();
    for tally in tallies {
        let t = tally?;
        total.sent += t.sent;
        total.answered += t.answered;
        total.rejected += t.rejected;
        total.lat_us.extend(t.lat_us);
        total.bitwise_checked += t.bitwise_checked;
        total.bitwise_bad += t.bitwise_bad;
    }
    total.lat_us.sort_unstable();
    Ok(LoadgenReport {
        sent: total.sent,
        answered: total.answered,
        rejected: total.rejected,
        wall_s,
        qps: total.answered as f64 / wall_s,
        p50_ms: percentile_ms(&total.lat_us, 0.50),
        p95_ms: percentile_ms(&total.lat_us, 0.95),
        p99_ms: percentile_ms(&total.lat_us, 0.99),
        bitwise_checked: total.bitwise_checked,
        bitwise_ok: total.bitwise_bad == 0,
    })
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;
    use std::io::Write;

    fn test_model(d: usize, seed: u64) -> Arc<ServeModel> {
        let mlp = Mlp::init(d, &mut Xoshiro256pp::new(seed));
        Arc::new(ServeModel::new(mlp, "sg2", "probe").unwrap())
    }

    fn fast_deadlines() -> Deadlines {
        Deadlines::resolve([Some(5), Some(5), Some(30)], None)
    }

    fn test_opts() -> ServeOpts {
        ServeOpts {
            deadlines: fast_deadlines(),
            threads: 2,
            microbatch: 4,
            queue_cap: 64,
            max_batch: 64,
            metrics_interval: Duration::from_millis(20),
            eval_delay: None,
        }
    }

    /// Bind loopback, spawn the serve loop for `max_conns` sessions,
    /// return the address and the join handle.
    fn spawn_serve(
        model: Arc<ServeModel>,
        opts: ServeOpts,
        max_conns: usize,
        metrics: Option<MetricsLogger>,
    ) -> (String, std::thread::JoinHandle<Result<()>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            serve_queries(listener, model, opts, Some(max_conns), metrics)
        });
        (addr, handle)
    }

    fn points(d: usize, n: usize, seed: u64) -> Vec<f32> {
        random_batch(&mut Xoshiro256pp::new(seed), n, d)
    }

    /// End-to-end loopback: served answers are bitwise the local
    /// forward, microbatch boundaries included (microbatch=4, n=9
    /// spans three slices), STATS reflects the traffic, and the
    /// metrics stream leaves parseable snapshot lines.
    #[test]
    fn serve_loopback_answers_match_local_forward_bitwise() {
        let d = 6;
        let model = test_model(d, 42);
        let dir = std::env::temp_dir().join(format!("hte-serve-e2e-{}", std::process::id()));
        let metrics_path = dir.join("serve.jsonl");
        let metrics = MetricsLogger::to_file(&metrics_path).unwrap();
        let (addr, handle) = spawn_serve(Arc::clone(&model), test_opts(), 1, Some(metrics));
        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        assert_eq!(client.max_batch, 64);
        for (i, n) in [1usize, 5, 9].into_iter().enumerate() {
            let xs = points(d, n, 100 + i as u64);
            match client.query(&xs).unwrap() {
                QueryReply::Answer(values) => {
                    let expected = model.eval(&xs);
                    assert_eq!(values.len(), n);
                    for (j, (e, g)) in expected.iter().zip(&values).enumerate() {
                        assert_eq!(e.to_bits(), g.to_bits(), "n={n} point {j} diverged");
                    }
                }
                QueryReply::Rejected(why) => panic!("unsaturated server rejected: {why}"),
            }
        }
        let stats = client.stats().unwrap();
        let parsed = Value::parse(&stats).unwrap();
        assert_eq!(parsed.get("queries").unwrap().as_usize().unwrap(), 3);
        assert_eq!(parsed.get("points").unwrap().as_usize().unwrap(), 15);
        assert_eq!(parsed.get("rejected").unwrap().as_usize().unwrap(), 0);
        drop(client);
        handle.join().unwrap().unwrap();
        let text = std::fs::read_to_string(&metrics_path).unwrap();
        let last = text.trim().lines().last().expect("metrics stream left no snapshot");
        let snap = Value::parse(last).unwrap();
        assert_eq!(snap.get("queries").unwrap().as_usize().unwrap(), 3);
        assert!(snap.get("qps").unwrap().as_f64().unwrap() > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A mismatched HELLO is rejected during the handshake with the
    /// offending field named — wrong d and wrong family both.
    #[test]
    fn serve_rejects_mismatched_hello_by_name() {
        let model = test_model(6, 43);
        let (addr, handle) = spawn_serve(Arc::clone(&model), test_opts(), 2, None);
        let dl = fast_deadlines();
        // wrong d: the client constructor itself surfaces the server error
        let err = ServeClient::connect(&addr, 8, &dl).unwrap_err().to_string();
        assert!(err.contains("d=8"), "{err}");
        assert!(err.contains("d=6"), "{err}");
        // wrong family, right dims: hand-rolled hello
        let spec = JobSpec {
            family: "bihar".into(),
            method: String::new(),
            lambda_g: 0.0,
            d: 6,
            n_params: Mlp::n_params_for(6),
        };
        let mut stream = connect_worker(&addr, dl.connect).unwrap();
        stream.set_read_timeout(Some(dl.handshake)).ok();
        write_frame(&mut stream, TAG_HELLO, &encode_hello(&spec)).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, TAG_ERROR);
        let msg = Dec::new(&payload).str().unwrap().to_string();
        assert!(msg.contains("bihar"), "{msg}");
        assert!(msg.contains("sg2"), "{msg}");
        drop(stream);
        handle.join().unwrap().unwrap();
    }

    /// Protocol violations are fatal to the connection: garbage magic,
    /// an absurd length word, and a mis-sized query payload each drop
    /// the session (the last one with a named ERROR first).
    #[test]
    fn serve_drops_malformed_and_oversized_frames() {
        let d = 4;
        let model = test_model(d, 44);
        let (addr, handle) = spawn_serve(Arc::clone(&model), test_opts(), 3, None);
        let dl = fast_deadlines();
        // 1: garbage magic after a good handshake
        {
            let mut client = ServeClient::connect(&addr, d, &dl).unwrap();
            let mut head = [0u8; 13];
            head[..4].copy_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
            head[4] = TAG_QUERY;
            client.stream.write_all(&head).unwrap();
            client.stream.flush().unwrap();
            // server drops us: the next read sees EOF (an error)
            assert!(client.read_reply().is_err());
        }
        // 2: absurd length word (> MAX_FRAME)
        {
            let mut client = ServeClient::connect(&addr, d, &dl).unwrap();
            let mut head = Vec::new();
            head.extend_from_slice(&super::super::cluster::FRAME_MAGIC.to_le_bytes());
            head.push(TAG_QUERY);
            head.extend_from_slice(&(u64::MAX).to_le_bytes());
            client.stream.write_all(&head).unwrap();
            client.stream.flush().unwrap();
            assert!(client.read_reply().is_err());
        }
        // 3: query claiming n=3 but shipping 2 points
        {
            let mut client = ServeClient::connect(&addr, d, &dl).unwrap();
            let mut e = Enc::default();
            e.u64(0);
            e.u64(3);
            e.f32s(&points(d, 2, 7));
            write_frame(&mut client.stream, TAG_QUERY, &e.buf).unwrap();
            let err = client.read_reply().unwrap_err().to_string();
            assert!(err.contains("claims n=3"), "{err}");
        }
        handle.join().unwrap().unwrap();
    }

    /// Saturation is answered, not dropped: with one slow evaluator
    /// and a one-deep queue, a burst of pipelined queries gets a
    /// status-1 rejection for the overflow and bit-exact answers for
    /// the rest — every id accounted for, connection still usable.
    #[test]
    fn serve_saturation_rejects_gracefully_and_answers_the_rest() {
        let d = 4;
        let model = test_model(d, 45);
        let opts = ServeOpts {
            threads: 1,
            queue_cap: 1,
            eval_delay: Some(Duration::from_millis(50)),
            ..test_opts()
        };
        let (addr, handle) = spawn_serve(Arc::clone(&model), opts, 1, None);
        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        let total = 10usize;
        let mut batches = Vec::new();
        for i in 0..total {
            let xs = points(d, 2, 200 + i as u64);
            let id = client.send_query(&xs).unwrap();
            batches.push((id, xs));
        }
        let (mut answered, mut rejected) = (0usize, 0usize);
        for _ in 0..total {
            let (id, reply) = client.read_reply().unwrap();
            let (_, xs) = batches.iter().find(|(b, _)| *b == id).expect("unknown id");
            match reply {
                QueryReply::Answer(values) => {
                    answered += 1;
                    let expected = model.eval(xs);
                    assert!(bits_match(&expected, &values), "answer {id} diverged");
                }
                QueryReply::Rejected(why) => {
                    rejected += 1;
                    assert!(why.contains("saturated"), "{why}");
                }
            }
        }
        assert!(rejected >= 1, "a 1-deep queue under a 10-query burst must reject");
        assert!(answered >= 1, "the queued query must still answer");
        assert_eq!(answered + rejected, total);
        // the connection survived saturation: one more round trip works
        let xs = points(d, 1, 999);
        match client.query(&xs).unwrap() {
            QueryReply::Answer(values) => assert!(bits_match(&model.eval(&xs), &values)),
            QueryReply::Rejected(why) => panic!("post-saturation query rejected: {why}"),
        }
        drop(client);
        handle.join().unwrap().unwrap();
    }

    /// A connected-but-stalled client (half a frame header, then
    /// silence) is shed by the handshake deadline and cannot wedge the
    /// server: a well-behaved client connecting afterwards is served.
    #[test]
    fn serve_sheds_stalled_client_by_deadline() {
        let d = 4;
        let model = test_model(d, 46);
        let opts = ServeOpts {
            deadlines: Deadlines::resolve([Some(2), Some(1), Some(5)], None),
            ..test_opts()
        };
        let (addr, handle) = spawn_serve(Arc::clone(&model), opts, 2, None);
        // the staller: half a header, then nothing
        let mut staller = connect_worker(&addr, Duration::from_secs(2)).unwrap();
        staller.write_all(&[0x50, 0x45, 0x54, 0x48, TAG_HELLO]).unwrap();
        staller.flush().unwrap();
        // a healthy client right behind it is served normally
        let dl = Deadlines::resolve([Some(2), Some(5), Some(5)], None);
        let mut client = ServeClient::connect(&addr, d, &dl).unwrap();
        let xs = points(d, 3, 300);
        match client.query(&xs).unwrap() {
            QueryReply::Answer(values) => assert!(bits_match(&model.eval(&xs), &values)),
            QueryReply::Rejected(why) => panic!("rejected: {why}"),
        }
        drop(client);
        drop(staller); // the deadline has long since shed it server-side
        handle.join().unwrap().unwrap();
    }

    /// Closed-loop loadgen: every request answered, every answer
    /// bitwise-verified, throughput measured.
    #[test]
    fn serve_loadgen_closed_loop_is_bitwise_clean() {
        let d = 5;
        let model = test_model(d, 47);
        let (addr, handle) = spawn_serve(Arc::clone(&model), test_opts(), 2, None);
        let opts = LoadgenOpts {
            addr,
            d,
            arrival: Arrival::Closed,
            rate: 0.0,
            conns: 2,
            batch: 3,
            requests: 8,
            seed: 9,
            deadlines: fast_deadlines(),
        };
        let report = run_loadgen(&opts, Some(&model)).unwrap();
        assert_eq!(report.sent, 8);
        assert_eq!(report.answered, 8);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.bitwise_checked, 8);
        assert!(report.bitwise_ok, "served bits diverged from the local forward");
        assert!(report.qps > 0.0);
        handle.join().unwrap().unwrap();
        // the report serializes to parseable JSON
        let parsed = Value::parse(&report.to_json()).unwrap();
        assert_eq!(parsed.get("answered").unwrap().as_usize().unwrap(), 8);
        assert!(matches!(parsed.get("bitwise_ok").unwrap(), Value::Bool(true)));
    }

    /// Open-loop loadgen: paced arrivals with pipelined out-of-order
    /// replies — every query accounted for (answered or rejected) and
    /// every answer bitwise-verified.
    #[test]
    fn serve_loadgen_open_loop_accounts_for_every_query() {
        let d = 5;
        let model = test_model(d, 48);
        let (addr, handle) = spawn_serve(Arc::clone(&model), test_opts(), 2, None);
        let opts = LoadgenOpts {
            addr,
            d,
            arrival: Arrival::Open,
            rate: 400.0,
            conns: 2,
            batch: 2,
            requests: 12,
            seed: 10,
            deadlines: fast_deadlines(),
        };
        let report = run_loadgen(&opts, Some(&model)).unwrap();
        assert_eq!(report.sent, 12);
        assert_eq!(report.answered + report.rejected, 12);
        assert_eq!(report.bitwise_checked, report.answered);
        assert!(report.bitwise_ok, "served bits diverged from the local forward");
        handle.join().unwrap().unwrap();
    }

    /// Percentiles and snapshot serialization: known latencies come
    /// back at the right ranks, and the JSON parses.
    #[test]
    fn serve_snapshot_percentiles_and_json() {
        let stats = ServeStats::new();
        for ms in 1..=100u64 {
            stats.record_answer(4, Duration::from_millis(ms));
        }
        stats.record_rejection();
        let snap = stats.snapshot(3);
        assert_eq!(snap.queries, 100);
        assert_eq!(snap.points, 400);
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.queue_depth, 3);
        assert!((snap.p50_ms - 50.0).abs() <= 1.0, "p50 {}", snap.p50_ms);
        assert!((snap.p95_ms - 95.0).abs() <= 1.0, "p95 {}", snap.p95_ms);
        assert!((snap.p99_ms - 99.0).abs() <= 1.0, "p99 {}", snap.p99_ms);
        let parsed = Value::parse(&snap.to_json()).unwrap();
        assert_eq!(parsed.get("queries").unwrap().as_usize().unwrap(), 100);
        assert_eq!(parsed.get("queue_depth").unwrap().as_usize().unwrap(), 3);
        // empty stats: percentiles are 0, not NaN/panic
        assert_eq!(percentile_ms(&[], 0.99), 0.0);
    }
}
