//! `hte-pinn serve`: a batched, observable inference tier for trained
//! PINN surrogates (DESIGN.md §11).
//!
//! A serve process loads one checkpoint, reconstructs the constrained
//! model (`factor(x) * mlp(x)`, the same [`Mlp::forward_constrained`]
//! the trainer evaluates), and answers `[n, d]` query batches over the
//! cluster's framed wire protocol — same `[magic][tag][len]` framing,
//! same HELLO handshake, three new tags (`QUERY`/`ANSWER`/`STATS`).
//!
//! Design constraints, in order:
//!
//! 1. **Bitwise determinism.**  A served answer is the bits a local
//!    [`Mlp::forward_constrained`] call would have produced for the
//!    same checkpoint and the same point — regardless of batch size,
//!    microbatch boundary, evaluator-thread count, or SIMD dispatch
//!    level.  The whole chain is row-independent: the matmul kernels
//!    accumulate each output row in a fixed k-order (`tensor::matmul`),
//!    so [`Mlp::forward_batch`] equals per-point `forward` to the bit,
//!    and microbatch splits only re-group rows.
//! 2. **No hangs, bounded memory.**  The request queue is bounded;
//!    when it is full the server *answers* — an [`TAG_ANSWER`] frame
//!    with a rejected status and a diagnostic string, never a silent
//!    drop or an unbounded buffer.  Every socket phase carries the
//!    per-phase [`Deadlines`] (PR 6): a connected-but-silent client is
//!    shed on the handshake deadline, a wedged one on the step
//!    deadline, and neither can stall other connections (one handler
//!    thread per connection).
//! 3. **Observable.**  Per-request latency, throughput, queue depth
//!    and rejection counts are kept server-side and exported two ways:
//!    a [`TAG_STATS`] request answers with a JSON snapshot, and
//!    `--metrics FILE` streams the same snapshots as JSONL through the
//!    training tier's [`MetricsLogger`].
//!
//! Protocol (after the shared HELLO/HELLO_ACK handshake — the client's
//! HELLO may leave family/method empty as a wildcard; `d`/`n_params`
//! are always cross-checked):
//!
//! ```text
//! client                                server
//!   HELLO {version, family, method,
//!          lambda_g, d, n_params}    ->
//!                                    <- HELLO_ACK {"serve", family, d,
//!                                                  n_params, max_batch}
//!                                       (or ERROR {message})
//!   pipelined:
//!   QUERY {id, n, xs[n*d]}          ->
//!                                    <- ANSWER {id, status=0,
//!                                               model_version, ckpt_step,
//!                                               u[n] f64}
//!                                       (or ANSWER {id, status=1,
//!                                        model_version, ckpt_step, why}
//!                                        on saturation / oversize)
//!   STATS {}                        ->
//!                                    <- STATS {json snapshot}
//!   (connection drop = goodbye; malformed frames are fatal: ERROR)
//! ```
//!
//! Answers to pipelined queries may arrive out of submission order
//! (the evaluator pool is concurrent) — clients match on `id`.
//!
//! **Hot checkpoint reload** (DESIGN.md §13): the served model lives in
//! a [`SharedModel`] epoch cell.  A [`ReloadPlan`] (SIGHUP and/or file
//! watch) re-reads the checkpoint off the serving path, validates the
//! header against the live spec (family/d/n_params must match — a
//! mismatch is rejected by name and the old model keeps serving), and
//! swaps the `Arc<ServeModel>` atomically *between* jobs, so in-flight
//! connections never drop and every answer names the
//! `model_version`/`ckpt_step` that produced it.

use std::collections::{HashMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant, SystemTime};

use anyhow::{bail, Context, Result};

use crate::checkpoint;
use crate::coordinator::{problem_for, MetricsLogger};
use crate::autodiff::{plan_enabled, Tape};
use crate::nn::{forward_batch_planned, ForwardScratch, Mlp};
use crate::pde::PdeProblem;
use crate::rng::Xoshiro256pp;

use super::cluster::{
    addr_salt, backoff_delay, connect_worker, encode_hello, read_frame, read_frame_or_eof,
    send_error, write_frame, Deadlines, Dec, Enc, JobSpec, PROTOCOL_VERSION, TAG_ANSWER,
    TAG_ERROR, TAG_HELLO, TAG_HELLO_ACK, TAG_QUERY, TAG_STATS,
};
use super::fault::{FaultAction, FaultPlan, FaultState};

/// [`TAG_ANSWER`] status word: the batch was evaluated, `n` f64 values
/// follow.
pub(crate) const ANSWER_OK: u32 = 0;
/// [`TAG_ANSWER`] status word: the batch was *not* evaluated (queue
/// saturated or batch oversized); a diagnostic string follows.  The
/// connection stays usable — rejection is backpressure, not an error.
pub(crate) const ANSWER_REJECTED: u32 = 1;

/// Latency ring capacity: percentiles are computed over the most
/// recent `LAT_CAP` answered queries (bounded memory at any uptime).
const LAT_CAP: usize = 1 << 16;

// ---------------------------------------------------------------------------
// The servable model
// ---------------------------------------------------------------------------

/// A trained constrained model, rebuilt from a checkpoint: the MLP
/// weights plus the problem family's hard-constraint factor.  `Send +
/// Sync` (the problem trait requires it), so one instance is shared by
/// every evaluator thread behind an `Arc`.
pub struct ServeModel {
    pub mlp: Mlp,
    problem: Box<dyn PdeProblem>,
    /// The job spec served clients are validated against (family,
    /// method, d, n_params — same struct the training handshake uses).
    pub spec: JobSpec,
    /// Training step the checkpoint was saved at (surfaced in logs).
    pub step: usize,
}

/// Per-evaluator-thread scratch for [`ServeModel::eval_batch`]: the
/// forward ping-pong buffers plus factor/value staging, so the steady
/// state of a serving thread allocates nothing.
#[derive(Default)]
pub struct EvalScratch {
    fwd: ForwardScratch,
    factors: Vec<f64>,
    vals: Vec<f64>,
    /// Raw (unconstrained) forward values for the planned path.
    raw: Vec<f32>,
    /// Recorder/replayer for forward-only plans (one plan per batch
    /// shape, cached per evaluator thread).
    tape: Tape,
}

impl ServeModel {
    /// Build a servable model around explicit weights (tests, benches).
    pub fn new(mlp: Mlp, family: &str, method: &str) -> Result<Self> {
        let problem = problem_for(family, mlp.d)?;
        let spec = JobSpec {
            family: family.to_string(),
            method: method.to_string(),
            lambda_g: 0.0,
            d: mlp.d,
            n_params: mlp.n_params(),
        };
        Ok(Self { mlp, problem, spec, step: 0 })
    }

    /// Rebuild the constrained model from a training checkpoint: the
    /// state payload is the optimizer layout `params|m|v|t` (3n+1
    /// floats), and serving needs only the leading `n` parameters.
    pub fn from_checkpoint(path: impl AsRef<Path>) -> Result<Self> {
        let (meta, state) = checkpoint::load(&path)
            .with_context(|| format!("loading checkpoint {:?}", path.as_ref()))?;
        let n = meta.model.n_params;
        if state.len() != 3 * n + 1 {
            bail!(
                "checkpoint state holds {} floats but the optimizer layout for {} parameters \
                 is {} (params|m|v|t) — not a training checkpoint this binary can serve",
                state.len(),
                n,
                3 * n + 1
            );
        }
        let mut mlp = Mlp::init(meta.model.d, &mut Xoshiro256pp::new(meta.config.seed));
        mlp.unpack_into(&state[..n]);
        let problem = problem_for(&meta.model.family, meta.model.d)
            .context("rebuilding the checkpoint's problem family")?;
        Ok(Self {
            mlp,
            problem,
            spec: JobSpec::from_config(&meta.config),
            step: meta.step,
        })
    }

    pub fn d(&self) -> usize {
        self.mlp.d
    }

    /// Evaluate `[n, d]` points, *appending* `n` constrained values to
    /// `out`.  Bitwise equal per point to
    /// [`Mlp::forward_constrained`] — the factor is computed by the
    /// same `PdeProblem::factor` the trainer's evaluator calls, and the
    /// batched forward is row-independent (see the module docs).
    pub fn eval_batch(&self, xs: &[f32], n: usize, out: &mut Vec<f64>, scratch: &mut EvalScratch) {
        assert_eq!(xs.len(), n * self.mlp.d, "xs must be [n, d] row-major");
        scratch.factors.clear();
        scratch.factors.extend(xs.chunks_exact(self.mlp.d).map(|x| self.problem.factor(x)));
        if plan_enabled() {
            // Forward-only plan replay: bitwise the eager batched
            // forward (DESIGN.md §12), amortizing graph construction
            // across the steady stream of same-shape microbatches.
            // With fusion on (default), the plan compiles each hidden
            // layer to one `MatmulBiasTanh` superinstruction and the
            // output layer to `MatmulBias` (Pass E) — same kernels in
            // the same order, so the served bits are unchanged.
            forward_batch_planned(&mut scratch.tape, &self.mlp, xs, n, &mut scratch.raw);
            out.extend(
                scratch.raw.iter().zip(&scratch.factors).map(|(&u, &f)| f * u as f64),
            );
            return;
        }
        self.mlp
            .forward_constrained_batch(xs, n, &scratch.factors, &mut scratch.vals, &mut scratch.fwd);
        out.extend_from_slice(&scratch.vals);
    }

    /// Allocating convenience around [`ServeModel::eval_batch`] (the
    /// loadgen verifier and tests compute expected bits through this).
    pub fn eval(&self, xs: &[f32]) -> Vec<f64> {
        let n = xs.len() / self.mlp.d;
        let mut out = Vec::with_capacity(n);
        self.eval_batch(xs, n, &mut out, &mut EvalScratch::default());
        out
    }
}

// ---------------------------------------------------------------------------
// Hot checkpoint reload
// ---------------------------------------------------------------------------

/// One generation of the served model: the weights plus the serving
/// version they answer as.  Versions start at 1 and bump on every
/// successful reload; version 0 is reserved for answers no model
/// produced (router-local rejections).
#[derive(Clone)]
pub struct ModelEpoch {
    pub model: Arc<ServeModel>,
    pub version: u64,
}

/// The reload-atomicity cell: evaluators pin one epoch per job (an
/// `Arc` clone under a short lock), a reload validates the incoming
/// checkpoint completely *before* swapping, and the swap itself is one
/// pointer store — so a batch is answered entirely by one model, a
/// failed reload leaves the previous epoch serving, and no connection
/// ever drops for a swap.
pub struct SharedModel {
    current: Mutex<ModelEpoch>,
}

impl SharedModel {
    pub fn new(model: Arc<ServeModel>) -> Self {
        SharedModel { current: Mutex::new(ModelEpoch { model, version: 1 }) }
    }

    /// The epoch answering right now (cheap: one `Arc` clone).
    pub fn current(&self) -> ModelEpoch {
        self.current.lock().expect("model lock poisoned").clone()
    }

    /// Re-read `path` and swap it in as the next epoch.  The checkpoint
    /// is fully loaded and validated first — CRC (v3), header sanity,
    /// and the serving invariants family/d/n_params against the live
    /// spec, each rejected by name — so any error leaves the current
    /// epoch untouched and still serving.
    pub fn reload_from(&self, path: impl AsRef<Path>) -> Result<ModelEpoch> {
        let fresh = ServeModel::from_checkpoint(&path)
            .with_context(|| format!("reloading checkpoint {:?}", path.as_ref()))?;
        let live = self.current();
        let spec = &live.model.spec;
        if fresh.spec.family != spec.family {
            bail!(
                "reload rejected: checkpoint {:?} is a {} model but this server is serving {}",
                path.as_ref(),
                fresh.spec.family,
                spec.family
            );
        }
        if fresh.spec.d != spec.d {
            bail!(
                "reload rejected: checkpoint {:?} has d={} but this server is serving d={}",
                path.as_ref(),
                fresh.spec.d,
                spec.d
            );
        }
        if fresh.spec.n_params != spec.n_params {
            bail!(
                "reload rejected: checkpoint {:?} has {} parameters but this server is \
                 serving {} — mixed architectures?",
                path.as_ref(),
                fresh.spec.n_params,
                spec.n_params
            );
        }
        let mut cur = self.current.lock().expect("model lock poisoned");
        let epoch = ModelEpoch { model: Arc::new(fresh), version: cur.version + 1 };
        *cur = epoch.clone();
        Ok(epoch)
    }
}

/// When and from where a serve process hot-reloads its checkpoint.
#[derive(Clone, Debug)]
pub struct ReloadPlan {
    /// Checkpoint file re-read on every trigger.
    pub path: PathBuf,
    /// Reload when the process receives SIGHUP (`serve --reload-on sighup`).
    pub on_sighup: bool,
    /// Reload when `path`'s mtime changes (`serve --watch` — follows a
    /// training run's `--save-every` autosaves; the trainer's
    /// write-then-rename keeps every observed file complete, and the v3
    /// CRC rejects anything torn anyway).
    pub watch: bool,
    /// How often the reloader thread checks its triggers.
    pub poll: Duration,
}

/// SIGHUP latch for `--reload-on sighup`: the handler only flips an
/// atomic (async-signal-safe); the reloader thread polls and clears it.
#[cfg(unix)]
mod sighup {
    use std::sync::atomic::{AtomicBool, Ordering};

    static PENDING: AtomicBool = AtomicBool::new(false);
    /// POSIX guarantees SIGHUP == 1 on every unix we target.
    const SIGHUP_NO: i32 = 1;

    extern "C" fn on_sighup(_sig: i32) {
        PENDING.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub fn install() {
        unsafe {
            signal(SIGHUP_NO, on_sighup);
        }
    }

    pub fn take_pending() -> bool {
        PENDING.swap(false, Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sighup {
    pub fn install() {}
    pub fn take_pending() -> bool {
        false
    }
}

fn mtime_of(path: &Path) -> Option<SystemTime> {
    std::fs::metadata(path).and_then(|m| m.modified()).ok()
}

// ---------------------------------------------------------------------------
// Server knobs
// ---------------------------------------------------------------------------

/// Serving knobs.  Defaults come from the environment-resolved
/// [`Deadlines`] and conservative capacity constants; tests override
/// everything explicitly.
#[derive(Clone)]
pub struct ServeOpts {
    pub deadlines: Deadlines,
    /// Evaluator threads draining the shared queue.
    pub threads: usize,
    /// Points per SIMD matmul call: a large request is split into
    /// `microbatch`-point slices so one huge query cannot hold an
    /// evaluator's working set beyond cache (splits never change bits —
    /// rows are independent).
    pub microbatch: usize,
    /// Bounded queue capacity, in *requests*.  A full queue rejects
    /// gracefully (status-1 ANSWER), it never buffers unboundedly.
    pub queue_cap: usize,
    /// Largest accepted `n` per query; larger batches are rejected
    /// with a named diagnostic (the cap is advertised in the ACK).
    pub max_batch: usize,
    /// How often the metrics reporter snapshots to the JSONL stream.
    pub metrics_interval: Duration,
    /// Test hook: hold each evaluated request this long *before*
    /// evaluating, making saturation deterministic in tests.  `None`
    /// (always, outside tests) evaluates immediately.
    pub eval_delay: Option<Duration>,
    /// Hot checkpoint reload triggers; `None` serves one model forever.
    pub reload: Option<ReloadPlan>,
    /// Serve-phase fault injection (`serve --fault` / `HTE_FAULT`) for
    /// the router chaos harness; the default plan injects nothing.
    pub fault: FaultPlan,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            deadlines: Deadlines::from_env(),
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            microbatch: 256,
            queue_cap: 64,
            max_batch: 16_384,
            metrics_interval: Duration::from_secs(1),
            eval_delay: None,
            reload: None,
            fault: FaultPlan::default(),
        }
    }
}

// ---------------------------------------------------------------------------
// Bounded queue + per-connection shared write side
// ---------------------------------------------------------------------------

/// The write half of one client connection, shared between its handler
/// thread (rejections, stats) and every evaluator thread (answers).
/// Frames are written whole under the lock, so pipelined answers never
/// interleave mid-frame.
struct ConnShared {
    stream: Mutex<TcpStream>,
    /// Cleared on the first write error; later answers for this
    /// connection are dropped instead of erroring every evaluator.
    alive: AtomicBool,
}

impl ConnShared {
    fn send(&self, tag: u8, payload: &[u8]) {
        if !self.alive.load(Ordering::Acquire) {
            return;
        }
        let mut stream = self.stream.lock().expect("conn lock poisoned");
        if write_frame(&mut stream, tag, payload).is_err() {
            self.alive.store(false, Ordering::Release);
        }
    }
}

/// One accepted query waiting for an evaluator.
struct Job {
    id: u64,
    n: usize,
    xs: Vec<f32>,
    accepted: Instant,
    conn: Arc<ConnShared>,
}

struct QueueInner {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Bounded MPMC queue: handlers push (failing fast when full — that
/// failure *is* the backpressure signal), evaluators block on pop.
struct Queue {
    inner: Mutex<QueueInner>,
    avail: Condvar,
    cap: usize,
}

impl Queue {
    fn new(cap: usize) -> Self {
        Queue {
            inner: Mutex::new(QueueInner { jobs: VecDeque::new(), shutdown: false }),
            avail: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Non-blocking: `Err(job)` hands the job back when the queue is
    /// full (the handler turns it into a status-1 ANSWER).
    fn push(&self, job: Job) -> std::result::Result<(), Job> {
        let mut inner = self.inner.lock().expect("queue lock poisoned");
        if inner.shutdown || inner.jobs.len() >= self.cap {
            return Err(job);
        }
        inner.jobs.push_back(job);
        drop(inner);
        self.avail.notify_one();
        Ok(())
    }

    /// Blocking: `None` once shut down *and* drained.
    fn pop(&self) -> Option<Job> {
        let mut inner = self.inner.lock().expect("queue lock poisoned");
        loop {
            if let Some(job) = inner.jobs.pop_front() {
                return Some(job);
            }
            if inner.shutdown {
                return None;
            }
            inner = self.avail.wait(inner).expect("queue lock poisoned");
        }
    }

    fn depth(&self) -> usize {
        self.inner.lock().expect("queue lock poisoned").jobs.len()
    }

    fn shutdown(&self) {
        self.inner.lock().expect("queue lock poisoned").shutdown = true;
        self.avail.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Observability
// ---------------------------------------------------------------------------

struct StatsInner {
    /// Answered queries (status 0).
    queries: u64,
    /// Points across answered queries.
    points: u64,
    /// Status-1 rejections (saturation + oversize).
    rejected: u64,
    /// Ring of the most recent `LAT_CAP` accept→answer latencies, µs.
    lat_us: Vec<u64>,
}

/// Shared server-side counters; snapshots come out as
/// [`ServeSnapshot`].
pub struct ServeStats {
    inner: Mutex<StatsInner>,
    started: Instant,
}

impl ServeStats {
    fn new() -> Self {
        ServeStats {
            inner: Mutex::new(StatsInner {
                queries: 0,
                points: 0,
                rejected: 0,
                lat_us: Vec::new(),
            }),
            started: Instant::now(),
        }
    }

    fn record_answer(&self, n: usize, latency: Duration) {
        let us = latency.as_micros().min(u64::MAX as u128) as u64;
        let mut st = self.inner.lock().expect("stats lock poisoned");
        if st.lat_us.len() < LAT_CAP {
            st.lat_us.push(us);
        } else {
            let at = (st.queries % LAT_CAP as u64) as usize;
            st.lat_us[at] = us;
        }
        st.queries += 1;
        st.points += n as u64;
    }

    fn record_rejection(&self) {
        self.inner.lock().expect("stats lock poisoned").rejected += 1;
    }

    fn snapshot(&self, queue_depth: usize, model_version: u64, ckpt_step: u64) -> ServeSnapshot {
        let st = self.inner.lock().expect("stats lock poisoned");
        let elapsed_s = self.started.elapsed().as_secs_f64().max(1e-9);
        let (queries, points, rejected) = (st.queries, st.points, st.rejected);
        let mut lat = st.lat_us.clone();
        drop(st);
        lat.sort_unstable();
        ServeSnapshot {
            elapsed_s,
            queries,
            points,
            rejected,
            qps: queries as f64 / elapsed_s,
            p50_ms: percentile_ms(&lat, 0.50),
            p95_ms: percentile_ms(&lat, 0.95),
            p99_ms: percentile_ms(&lat, 0.99),
            queue_depth,
            model_version,
            ckpt_step,
        }
    }
}

/// Nearest-rank percentile over an ascending µs slice, in ms (0 when
/// empty — a fresh server has no latency story to tell yet).
fn percentile_ms(sorted_us: &[u64], q: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() - 1) as f64 * q).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)] as f64 / 1_000.0
}

/// One observability snapshot: the [`TAG_STATS`] reply body and the
/// `--metrics` JSONL line share this schema.
#[derive(Clone, Debug)]
pub struct ServeSnapshot {
    pub elapsed_s: f64,
    pub queries: u64,
    pub points: u64,
    pub rejected: u64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub queue_depth: usize,
    /// Serving generation of the model answering when the snapshot was
    /// taken (starts at 1, bumps on every hot reload).
    pub model_version: u64,
    /// Training step of that model's checkpoint.
    pub ckpt_step: u64,
}

impl ServeSnapshot {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"elapsed_s\":{:.3},\"queries\":{},\"points\":{},\"rejected\":{},\
             \"qps\":{:.3},\"p50_ms\":{:.3},\"p95_ms\":{:.3},\"p99_ms\":{:.3},\
             \"queue_depth\":{},\"model_version\":{},\"ckpt_step\":{}}}",
            self.elapsed_s,
            self.queries,
            self.points,
            self.rejected,
            self.qps,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.queue_depth,
            self.model_version,
            self.ckpt_step
        )
    }
}

// ---------------------------------------------------------------------------
// The serve loop
// ---------------------------------------------------------------------------

fn encode_answer_ok(id: u64, values: &[f64], model_version: u64, ckpt_step: u64) -> Vec<u8> {
    let mut e = Enc::default();
    e.u64(id);
    e.u32(ANSWER_OK);
    e.u64(model_version);
    e.u64(ckpt_step);
    e.f64s(values);
    e.buf
}

/// `pub(crate)` so the router can answer "no live replicas" in the same
/// wire shape; its locally-minted rejections carry model_version 0 —
/// no model produced them.
pub(crate) fn encode_answer_rejected(
    id: u64,
    why: &str,
    model_version: u64,
    ckpt_step: u64,
) -> Vec<u8> {
    let mut e = Enc::default();
    e.u64(id);
    e.u32(ANSWER_REJECTED);
    e.u64(model_version);
    e.u64(ckpt_step);
    e.str(why);
    e.buf
}

/// One evaluator thread: drain the queue until shutdown, microbatching
/// each request through the SIMD forward and answering on the
/// request's own connection.  The serving epoch is pinned once per job
/// (reload atomicity: a hot swap lands *between* jobs, so a batch is
/// answered entirely by one model and stamped with its version).
fn evaluator_loop(
    shared: &SharedModel,
    queue: &Queue,
    stats: &ServeStats,
    microbatch: usize,
    eval_delay: Option<Duration>,
) {
    let mb = microbatch.max(1);
    let mut scratch = EvalScratch::default();
    let mut out: Vec<f64> = Vec::new();
    while let Some(job) = queue.pop() {
        if let Some(delay) = eval_delay {
            std::thread::sleep(delay);
        }
        let epoch = shared.current();
        let model = &*epoch.model;
        let d = model.mlp.d;
        out.clear();
        let mut off = 0;
        while off < job.n {
            let take = (job.n - off).min(mb);
            model.eval_batch(&job.xs[off * d..(off + take) * d], take, &mut out, &mut scratch);
            off += take;
        }
        // count before sending: a client that has *seen* an answer can
        // never observe a stats snapshot that hasn't counted it yet
        // (latency therefore excludes the answer write — negligible)
        stats.record_answer(job.n, job.accepted.elapsed());
        job.conn.send(
            TAG_ANSWER,
            &encode_answer_ok(job.id, &out, epoch.version, model.step as u64),
        );
    }
}

/// Validate a serve client's HELLO against the loaded model.  Family
/// and method act as wildcards when empty — a generic client can dial
/// any surrogate, and the *server's* method is empty for a router
/// (the serve ACK does not carry it) — but `d` and `n_params` are
/// always cross-checked (a dimension mismatch would mis-stride every
/// query payload).  `pub(crate)`: the router handshakes clients with
/// the same rules against its replicas' agreed spec.
pub(crate) fn check_hello(payload: &[u8], spec: &JobSpec) -> Result<()> {
    let mut dec = Dec::new(payload);
    let version = dec.u32()?;
    if version != PROTOCOL_VERSION {
        bail!("client speaks protocol v{version}, this server speaks v{PROTOCOL_VERSION}");
    }
    let family = dec.str()?;
    let method = dec.str()?;
    let _lambda_g = dec.f32()?; // training-only knob, ignored at inference
    let d = dec.u64()? as usize;
    let n_params = dec.u64()? as usize;
    if d != spec.d {
        bail!("client expects d={d} but this server loaded a d={} checkpoint", spec.d);
    }
    if n_params != spec.n_params {
        bail!(
            "client expects {n_params} parameters but the loaded checkpoint has {} — \
             mixed binary versions?",
            spec.n_params
        );
    }
    if !family.is_empty() && family != spec.family {
        bail!(
            "client expects problem family {family} but this server loaded a {} checkpoint",
            spec.family
        );
    }
    if !method.is_empty() && !spec.method.is_empty() && method != spec.method {
        bail!(
            "client expects method {method} but this server loaded a {} checkpoint",
            spec.method
        );
    }
    Ok(())
}

/// One client session: handshake, then accept pipelined QUERY/STATS
/// frames until the client hangs up.  Protocol violations (bad magic,
/// absurd lengths, mis-sized payloads) are fatal to the *connection*;
/// saturation and oversize are answered gracefully on it.
fn handle_client(
    mut stream: TcpStream,
    shared: &SharedModel,
    queue: &Queue,
    stats: &ServeStats,
    fault: &Mutex<FaultState>,
    opts_max_batch: usize,
    dl: &Deadlines,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(dl.handshake)).ok();
    stream.set_write_timeout(Some(dl.handshake)).ok();
    let Some((tag, payload)) = read_frame_or_eof(&mut stream)? else {
        return Ok(()); // connected and left without a word (port scan)
    };
    if tag != TAG_HELLO {
        let _ = send_error(&mut stream, "expected a hello frame");
        bail!("expected a hello frame, got tag {tag}");
    }
    // family/d/n_params are reload invariants, so the handshake epoch's
    // spec stays valid for this whole session even across hot swaps
    let spec = shared.current().model.spec.clone();
    if let Err(e) = check_hello(&payload, &spec) {
        let _ = send_error(&mut stream, &format!("{e:#}"));
        return Err(e);
    }
    let mut ack = Enc::default();
    ack.str("serve");
    ack.str(&spec.family);
    ack.u64(spec.d as u64);
    ack.u64(spec.n_params as u64);
    ack.u64(opts_max_batch as u64);
    write_frame(&mut stream, TAG_HELLO_ACK, &ack.buf).context("sending serve ack")?;
    // Session established: queries run under the (longer) step deadline.
    stream.set_read_timeout(Some(dl.step)).ok();
    stream.set_write_timeout(Some(dl.step)).ok();
    let conn = Arc::new(ConnShared {
        stream: Mutex::new(stream.try_clone().context("cloning the answer stream")?),
        alive: AtomicBool::new(true),
    });
    let d = spec.d;
    loop {
        let Some((tag, payload)) = read_frame_or_eof(&mut stream)? else {
            return Ok(()); // clean goodbye
        };
        match tag {
            TAG_QUERY => {
                let (action, exit_process) = {
                    let mut st = fault.lock().expect("fault lock poisoned");
                    (st.on_query(), st.plan.exit_process)
                };
                match action {
                    FaultAction::None => {}
                    FaultAction::Die => {
                        if exit_process {
                            eprintln!("serve: fault injection: dying after the query budget");
                            std::process::exit(3);
                        }
                        // in-process replica: the state stays dead, so
                        // every connection from here on refuses queries
                        bail!("fault injection: replica died after its query budget");
                    }
                    FaultAction::DropConn => {
                        bail!("fault injection: dropping the connection on QUERY");
                    }
                    FaultAction::CorruptFrame => {
                        use std::io::Write as _;
                        let mut s = conn.stream.lock().expect("conn lock poisoned");
                        let mut head = [0u8; 13];
                        head[..4].copy_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
                        head[4] = TAG_ANSWER;
                        let _ = s.write_all(&head);
                        let _ = s.flush();
                        drop(s);
                        bail!("fault injection: corrupt answer frame on QUERY");
                    }
                }
                let accepted = Instant::now();
                let mut dec = Dec::new(&payload);
                let id = dec.u64()?;
                let n = dec.u64()? as usize;
                let mut xs = Vec::new();
                dec.f32s_into(&mut xs)?;
                if xs.len() != n * d {
                    // fatal: write through the shared side so the error
                    // frame can't interleave with an in-flight answer
                    let msg = format!(
                        "query {id} claims n={n} points at d={d} but ships {} coords",
                        xs.len()
                    );
                    let mut e = Enc::default();
                    e.str(&msg);
                    conn.send(TAG_ERROR, &e.buf);
                    bail!("{msg}");
                }
                if n > opts_max_batch {
                    stats.record_rejection();
                    let ep = shared.current();
                    conn.send(
                        TAG_ANSWER,
                        &encode_answer_rejected(
                            id,
                            &format!(
                                "batch of {n} points exceeds this server's max_batch \
                                 {opts_max_batch} — split the request"
                            ),
                            ep.version,
                            ep.model.step as u64,
                        ),
                    );
                    continue;
                }
                let job = Job { id, n, xs, accepted, conn: Arc::clone(&conn) };
                if let Err(job) = queue.push(job) {
                    stats.record_rejection();
                    let ep = shared.current();
                    conn.send(
                        TAG_ANSWER,
                        &encode_answer_rejected(
                            job.id,
                            &format!(
                                "server saturated: the {}-request queue is full — \
                                 back off and retry",
                                queue.cap
                            ),
                            ep.version,
                            ep.model.step as u64,
                        ),
                    );
                }
            }
            TAG_STATS => {
                let ep = shared.current();
                let mut e = Enc::default();
                e.str(
                    &stats
                        .snapshot(queue.depth(), ep.version, ep.model.step as u64)
                        .to_json(),
                );
                conn.send(TAG_STATS, &e.buf);
            }
            other => {
                let mut e = Enc::default();
                e.str(&format!("unexpected frame tag {other}"));
                conn.send(TAG_ERROR, &e.buf);
                bail!("unexpected frame tag {other}");
            }
        }
        if !conn.alive.load(Ordering::Acquire) {
            bail!("client write side failed — dropping the session");
        }
    }
}

/// The serve accept loop.  Spawns `opts.threads` evaluator threads
/// over one bounded queue, one handler thread per accepted connection,
/// (when `metrics` is given) a snapshot reporter on
/// `opts.metrics_interval`, and (when `opts.reload` is given) a
/// reloader thread polling the plan's triggers.
///
/// With `max_conns: Some(k)` the loop accepts exactly `k` connections,
/// joins their handlers, drains the queue, stops the evaluators and
/// flushes a final metrics snapshot before returning — the shape every
/// test and bench uses.  `None` serves forever (the CLI path).
pub fn serve_queries(
    listener: TcpListener,
    shared: Arc<SharedModel>,
    opts: ServeOpts,
    max_conns: Option<usize>,
    metrics: Option<MetricsLogger>,
) -> Result<()> {
    let queue = Arc::new(Queue::new(opts.queue_cap));
    let stats = Arc::new(ServeStats::new());
    let stop = Arc::new(AtomicBool::new(false));
    let fault = Arc::new(Mutex::new(FaultState::new(opts.fault.clone())));

    let mut evaluators = Vec::new();
    for _ in 0..opts.threads.max(1) {
        let shared = Arc::clone(&shared);
        let queue = Arc::clone(&queue);
        let stats = Arc::clone(&stats);
        let (mb, delay) = (opts.microbatch, opts.eval_delay);
        evaluators.push(std::thread::spawn(move || {
            evaluator_loop(&shared, &queue, &stats, mb, delay);
        }));
    }

    let reporter = metrics.map(|mut logger| {
        let stats = Arc::clone(&stats);
        let queue = Arc::clone(&queue);
        let stop = Arc::clone(&stop);
        let shared = Arc::clone(&shared);
        let interval = opts.metrics_interval;
        let snap = move |stats: &ServeStats, queue: &Queue, shared: &SharedModel| {
            let ep = shared.current();
            stats.snapshot(queue.depth(), ep.version, ep.model.step as u64).to_json()
        };
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                std::thread::sleep(interval);
                let _ = logger.log_line(&snap(&stats, &queue, &shared));
            }
            // final snapshot so even sub-interval runs leave a line
            let _ = logger.log_line(&snap(&stats, &queue, &shared));
            let _ = logger.finish();
        })
    });

    let reloader = opts.reload.clone().map(|plan| {
        let shared = Arc::clone(&shared);
        let stop = Arc::clone(&stop);
        if plan.on_sighup {
            sighup::install();
        }
        std::thread::spawn(move || {
            let mut last_mtime = mtime_of(&plan.path);
            while !stop.load(Ordering::Acquire) {
                std::thread::sleep(plan.poll);
                let mut due = plan.on_sighup && sighup::take_pending();
                if plan.watch {
                    let now = mtime_of(&plan.path);
                    if now.is_some() && now != last_mtime {
                        last_mtime = now;
                        due = true;
                    }
                }
                if !due {
                    continue;
                }
                match shared.reload_from(&plan.path) {
                    Ok(ep) => eprintln!(
                        "serve: reloaded checkpoint {:?} -> model_version {} (step {})",
                        plan.path, ep.version, ep.model.step
                    ),
                    Err(e) => eprintln!(
                        "serve: reload rejected — serving the previous model: {e:#}"
                    ),
                }
            }
        })
    });

    let mut handlers = Vec::new();
    let mut served = 0usize;
    for stream in listener.incoming() {
        let stream = stream.context("accepting a serve connection")?;
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
        let shared = Arc::clone(&shared);
        let queue = Arc::clone(&queue);
        let stats = Arc::clone(&stats);
        let fault = Arc::clone(&fault);
        let (max_batch, dl) = (opts.max_batch, opts.deadlines);
        let handle = std::thread::spawn(move || {
            if let Err(e) =
                handle_client(stream, &shared, &queue, &stats, &fault, max_batch, &dl)
            {
                eprintln!("serve: session with {peer} ended with an error: {e:#}");
            }
        });
        if max_conns.is_some() {
            handlers.push(handle);
        }
        served += 1;
        if let Some(max) = max_conns {
            if served >= max {
                break;
            }
        }
    }
    for h in handlers {
        let _ = h.join();
    }
    queue.shutdown();
    for h in evaluators {
        let _ = h.join();
    }
    stop.store(true, Ordering::Release);
    if let Some(r) = reporter {
        let _ = r.join();
    }
    if let Some(r) = reloader {
        let _ = r.join();
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// What one query came back as.
#[derive(Clone, Debug, PartialEq)]
pub enum QueryReply {
    /// Evaluated: one f64 per point, bit-for-bit the local forward,
    /// stamped with the serving generation and checkpoint step that
    /// produced it (so a client can assert *which* weights answered
    /// across a hot reload).
    Answer { values: Vec<f64>, model_version: u64, ckpt_step: u64 },
    /// Gracefully rejected (saturation / oversize) with the server's
    /// diagnostic; the connection remains usable.
    Rejected(String),
}

/// A serve-protocol client: dial, handshake, then `query` (one
/// outstanding) or `send_query`/`read_reply` (pipelined, match on id).
pub struct ServeClient {
    /// `pub(crate)`: the router relays raw QUERY/ANSWER payloads
    /// through this stream without re-encoding (bitwise pass-through).
    pub(crate) stream: TcpStream,
    pub d: usize,
    /// Problem family the server acked (the router cross-checks that
    /// all replicas agree).
    pub family: String,
    /// Parameter count the server acked.
    pub n_params: usize,
    /// Largest batch the server advertised in its ACK.
    pub max_batch: usize,
    next_id: u64,
}

impl ServeClient {
    /// Connect and handshake.  The HELLO carries empty family/method —
    /// the generic-client wildcard — plus `d` and the architecture's
    /// parameter count, which the server cross-checks.
    pub fn connect(addr: &str, d: usize, dl: &Deadlines) -> Result<Self> {
        let spec = JobSpec {
            family: String::new(),
            method: String::new(),
            lambda_g: 0.0,
            d,
            n_params: Mlp::n_params_for(d),
        };
        let mut stream = connect_worker(addr, dl.connect)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(dl.handshake)).ok();
        stream.set_write_timeout(Some(dl.handshake)).ok();
        write_frame(&mut stream, TAG_HELLO, &encode_hello(&spec))
            .context("sending the serve hello")?;
        let (tag, payload) = read_frame(&mut stream).context("waiting for the serve ack")?;
        match tag {
            TAG_HELLO_ACK => {
                let mut dec = Dec::new(&payload);
                let tier = dec.str()?;
                if tier != "serve" {
                    bail!(
                        "endpoint {addr} acked as {tier:?}, not a serve tier — \
                         dialed a training worker?"
                    );
                }
                let family = dec.str()?.to_string();
                let got_d = dec.u64()? as usize;
                let n_params = dec.u64()? as usize;
                let max_batch = dec.u64()? as usize;
                if got_d != d {
                    bail!("server acked d={got_d}, expected {d}");
                }
                stream.set_read_timeout(Some(dl.step)).ok();
                stream.set_write_timeout(Some(dl.step)).ok();
                Ok(ServeClient { stream, d, family, n_params, max_batch, next_id: 0 })
            }
            TAG_ERROR => {
                let mut dec = Dec::new(&payload);
                let msg = dec.str().unwrap_or("(unreadable error frame)");
                bail!("server {addr} rejected the handshake: {msg}")
            }
            other => bail!("server {addr} sent unexpected frame tag {other} during handshake"),
        }
    }

    /// Fire one `[n, d]` query without waiting; returns its id.
    /// Pipelined replies may come back in any order.
    pub fn send_query(&mut self, xs: &[f32]) -> Result<u64> {
        assert_eq!(xs.len() % self.d, 0, "xs must be [n, d] row-major");
        let id = self.next_id;
        self.next_id += 1;
        let mut e = Enc::default();
        e.u64(id);
        e.u64((xs.len() / self.d) as u64);
        e.f32s(xs);
        write_frame(&mut self.stream, TAG_QUERY, &e.buf).context("sending a query")?;
        Ok(id)
    }

    /// Read one ANSWER frame (any pipelined id).
    pub fn read_reply(&mut self) -> Result<(u64, QueryReply)> {
        let (tag, payload) = read_frame(&mut self.stream).context("waiting for an answer")?;
        match tag {
            TAG_ANSWER => Self::decode_answer(&payload),
            TAG_ERROR => {
                let mut dec = Dec::new(&payload);
                let msg = dec.str().unwrap_or("(unreadable error frame)");
                bail!("server error: {msg}")
            }
            other => bail!("expected an answer frame, got tag {other}"),
        }
    }

    pub(crate) fn decode_answer(payload: &[u8]) -> Result<(u64, QueryReply)> {
        let mut dec = Dec::new(payload);
        let id = dec.u64()?;
        let status = dec.u32()?;
        let model_version = dec.u64()?;
        let ckpt_step = dec.u64()?;
        match status {
            ANSWER_OK => {
                let mut values = Vec::new();
                dec.f64s_into(&mut values)?;
                Ok((id, QueryReply::Answer { values, model_version, ckpt_step }))
            }
            ANSWER_REJECTED => Ok((id, QueryReply::Rejected(dec.str()?.to_string()))),
            other => bail!("answer {id} carries unknown status {other}"),
        }
    }

    /// One blocking round trip (no other queries outstanding).
    pub fn query(&mut self, xs: &[f32]) -> Result<QueryReply> {
        let id = self.send_query(xs)?;
        let (got, reply) = self.read_reply()?;
        if got != id {
            bail!("answer id {got} does not match query id {id} — pipelined? use read_reply");
        }
        Ok(reply)
    }

    /// Fetch the server's observability snapshot (JSON).  Call with no
    /// queries outstanding — the reply shares the stream.
    pub fn stats(&mut self) -> Result<String> {
        write_frame(&mut self.stream, TAG_STATS, &[]).context("sending a stats request")?;
        let (tag, payload) = read_frame(&mut self.stream).context("waiting for stats")?;
        if tag != TAG_STATS {
            bail!("expected a stats frame, got tag {tag}");
        }
        let mut dec = Dec::new(&payload);
        Ok(dec.str()?.to_string())
    }
}

// ---------------------------------------------------------------------------
// Load generator
// ---------------------------------------------------------------------------

pub use crate::config::Arrival;

/// Load-generator shape: `conns` connections, `requests` total queries
/// of `batch` points each, either closed-loop (one outstanding per
/// connection — measures capacity) or open-loop at `rate` queries/sec
/// total (paced arrivals regardless of completions — measures behavior
/// under offered load, the model that actually saturates the queue).
pub struct LoadgenOpts {
    /// Serve/router endpoints; connection `c` dials
    /// `addrs[c % addrs.len()]`, so one run can drive a router and a
    /// bare replica side by side and diff their accounting.
    pub addrs: Vec<String>,
    pub d: usize,
    pub arrival: Arrival,
    /// Open-loop only: total offered queries/sec across connections.
    pub rate: f64,
    pub conns: usize,
    /// Points per query.
    pub batch: usize,
    /// Total queries across all connections.
    pub requests: usize,
    pub seed: u64,
    pub deadlines: Deadlines,
}

/// What a loadgen run measured.  `bitwise_ok` is the determinism gate:
/// every answered query was compared bit-for-bit against a local
/// [`ServeModel::eval`] when a verify model was supplied.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub sent: usize,
    pub answered: usize,
    pub rejected: usize,
    pub wall_s: f64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Answered queries that were bitwise-verified (0 without a model).
    pub bitwise_checked: usize,
    pub bitwise_ok: bool,
    /// Distinct `model_version` stamps seen across all answers,
    /// ascending — a reload mid-run shows up as `[1, 2]`.
    pub model_versions: Vec<u64>,
    /// Per-endpoint accounting, in `addrs` order.
    pub endpoints: Vec<EndpointReport>,
}

/// One endpoint's share of a loadgen run.
#[derive(Clone, Debug)]
pub struct EndpointReport {
    pub addr: String,
    pub sent: usize,
    pub answered: usize,
    pub rejected: usize,
    /// Connect attempts retried (transient dial failures during chaos).
    pub connect_retries: usize,
}

impl LoadgenReport {
    pub fn to_json(&self) -> String {
        let versions = self
            .model_versions
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join(",");
        let endpoints = self
            .endpoints
            .iter()
            .map(|ep| {
                format!(
                    "{{\"addr\":{:?},\"sent\":{},\"answered\":{},\"rejected\":{},\
                     \"connect_retries\":{}}}",
                    ep.addr, ep.sent, ep.answered, ep.rejected, ep.connect_retries
                )
            })
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"sent\":{},\"answered\":{},\"rejected\":{},\"wall_s\":{:.3},\
             \"qps\":{:.3},\"p50_ms\":{:.3},\"p95_ms\":{:.3},\"p99_ms\":{:.3},\
             \"bitwise_checked\":{},\"bitwise_ok\":{},\
             \"model_versions\":[{}],\"endpoints\":[{}]}}",
            self.sent,
            self.answered,
            self.rejected,
            self.wall_s,
            self.qps,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.bitwise_checked,
            self.bitwise_ok,
            versions,
            endpoints
        )
    }
}

/// What one connection's worth of load measured.
#[derive(Default)]
struct ConnTally {
    sent: usize,
    answered: usize,
    rejected: usize,
    lat_us: Vec<u64>,
    bitwise_checked: usize,
    bitwise_bad: usize,
    connect_retries: usize,
    /// Distinct model versions seen in answers (tiny: one per reload).
    versions: Vec<u64>,
}

impl ConnTally {
    fn saw_version(&mut self, v: u64) {
        if !self.versions.contains(&v) {
            self.versions.push(v);
        }
    }
}

/// Dial with up to two backoff retries (transient listener hiccups mid
/// chaos run are expected), tallying every retry for the report.
fn connect_with_retry(
    addr: &str,
    d: usize,
    dl: &Deadlines,
    tally: &mut ConnTally,
) -> Result<ServeClient> {
    let salt = addr_salt(addr);
    let mut attempt = 0u32;
    loop {
        match ServeClient::connect(addr, d, dl) {
            Ok(client) => return Ok(client),
            Err(_) if attempt < 2 => {
                tally.connect_retries += 1;
                std::thread::sleep(backoff_delay(attempt, salt));
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

fn random_batch(rng: &mut Xoshiro256pp, n: usize, d: usize) -> Vec<f32> {
    (0..n * d).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
}

/// Bit-compare an answer against the local model; returns true when
/// every value matches exactly.
fn bits_match(expected: &[f64], got: &[f64]) -> bool {
    expected.len() == got.len()
        && expected.iter().zip(got).all(|(a, b)| a.to_bits() == b.to_bits())
}

fn closed_loop_conn(
    opts: &LoadgenOpts,
    addr: &str,
    conn_idx: usize,
    n_requests: usize,
    verify: Option<&ServeModel>,
) -> Result<ConnTally> {
    let mut tally = ConnTally::default();
    let mut client = connect_with_retry(addr, opts.d, &opts.deadlines, &mut tally)?;
    let mut rng = Xoshiro256pp::new(opts.seed ^ (0x9E37 + conn_idx as u64));
    for _ in 0..n_requests {
        let xs = random_batch(&mut rng, opts.batch, opts.d);
        let t0 = Instant::now();
        let reply = client.query(&xs)?;
        tally.sent += 1;
        match reply {
            QueryReply::Answer { values, model_version, .. } => {
                tally.lat_us.push(t0.elapsed().as_micros() as u64);
                tally.answered += 1;
                tally.saw_version(model_version);
                if let Some(model) = verify {
                    tally.bitwise_checked += 1;
                    if !bits_match(&model.eval(&xs), &values) {
                        tally.bitwise_bad += 1;
                    }
                }
            }
            QueryReply::Rejected(_) => tally.rejected += 1,
        }
    }
    Ok(tally)
}

fn open_loop_conn(
    opts: &LoadgenOpts,
    addr: &str,
    conn_idx: usize,
    n_requests: usize,
    verify: Option<&ServeModel>,
) -> Result<ConnTally> {
    let mut pre_tally = ConnTally::default();
    let mut client = connect_with_retry(addr, opts.d, &opts.deadlines, &mut pre_tally)?;
    let connect_retries = pre_tally.connect_retries;
    let mut reader = client.stream.try_clone().context("cloning the reply stream")?;
    let mut rng = Xoshiro256pp::new(opts.seed ^ (0x9E37 + conn_idx as u64));
    // id -> (sent-at, expected bits when verifying)
    let pending: Mutex<HashMap<u64, (Instant, Option<Vec<f64>>)>> = Mutex::new(HashMap::new());
    let sent = AtomicUsize::new(0);
    let sender_done = AtomicBool::new(false);
    let per_conn_rate = (opts.rate / opts.conns.max(1) as f64).max(1e-9);
    let interval = Duration::from_secs_f64(1.0 / per_conn_rate);
    let mut tally = ConnTally::default();
    std::thread::scope(|scope| -> Result<()> {
        let reader_thread = scope.spawn(|| -> Result<ConnTally> {
            let mut t = ConnTally::default();
            loop {
                if sender_done.load(Ordering::Acquire)
                    && t.answered + t.rejected >= sent.load(Ordering::Acquire)
                {
                    return Ok(t);
                }
                let (tag, payload) =
                    read_frame(&mut reader).context("waiting for an open-loop answer")?;
                if tag == TAG_STATS {
                    continue; // the sender's end-of-run nudge: re-check above
                }
                if tag != TAG_ANSWER {
                    bail!("expected an answer frame, got tag {tag}");
                }
                let (id, reply) = ServeClient::decode_answer(&payload)?;
                let Some((t0, expected)) = pending.lock().expect("pending lock").remove(&id)
                else {
                    bail!("answer for unknown query id {id}");
                };
                match reply {
                    QueryReply::Answer { values, model_version, .. } => {
                        t.lat_us.push(t0.elapsed().as_micros() as u64);
                        t.answered += 1;
                        t.saw_version(model_version);
                        if let Some(expected) = expected {
                            t.bitwise_checked += 1;
                            if !bits_match(&expected, &values) {
                                t.bitwise_bad += 1;
                            }
                        }
                    }
                    QueryReply::Rejected(_) => t.rejected += 1,
                }
            }
        });
        let start = Instant::now();
        for i in 0..n_requests {
            let due = start + interval.mul_f64(i as f64);
            if let Some(wait) = due.checked_duration_since(Instant::now()) {
                std::thread::sleep(wait);
            }
            let xs = random_batch(&mut rng, opts.batch, opts.d);
            let expected = verify.map(|m| m.eval(&xs));
            // register before sending: the reader may win the race
            let id = client.next_id;
            pending.lock().expect("pending lock").insert(id, (Instant::now(), expected));
            match client.send_query(&xs) {
                Ok(sent_id) => debug_assert_eq!(sent_id, id),
                Err(e) => {
                    pending.lock().expect("pending lock").remove(&id);
                    sender_done.store(true, Ordering::Release);
                    return Err(e);
                }
            }
            sent.fetch_add(1, Ordering::Release);
        }
        sender_done.store(true, Ordering::Release);
        // Wake the reader if it blocked on read *before* seeing the
        // done flag: the stats reply is one guaranteed frame after the
        // flag flips, closing the check-then-block race.
        let _ = write_frame(&mut client.stream, TAG_STATS, &[]);
        tally = reader_thread.join().expect("open-loop reader panicked")?;
        tally.sent = sent.load(Ordering::Acquire);
        tally.connect_retries = connect_retries;
        Ok(())
    })?;
    Ok(tally)
}

/// Run the load generator against a serve endpoint.  With
/// `verify: Some(model)`, every answered query is compared bit-for-bit
/// against the local forward — the report's `bitwise_ok` is the serve
/// tier's determinism gate.
pub fn run_loadgen(opts: &LoadgenOpts, verify: Option<&ServeModel>) -> Result<LoadgenReport> {
    if opts.conns == 0 || opts.requests == 0 {
        bail!("loadgen needs at least one connection and one request");
    }
    if opts.addrs.is_empty() {
        bail!("loadgen needs at least one endpoint address");
    }
    let start = Instant::now();
    let tallies: Vec<Result<ConnTally>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..opts.conns {
            // split `requests` across connections, remainder to the low ranks
            let n_req = opts.requests / opts.conns + usize::from(c < opts.requests % opts.conns);
            let addr = opts.addrs[c % opts.addrs.len()].as_str();
            handles.push(scope.spawn(move || match opts.arrival {
                Arrival::Closed => closed_loop_conn(opts, addr, c, n_req, verify),
                Arrival::Open => open_loop_conn(opts, addr, c, n_req, verify),
            }));
        }
        handles.into_iter().map(|h| h.join().expect("loadgen connection panicked")).collect()
    });
    let wall_s = start.elapsed().as_secs_f64().max(1e-9);
    let mut total = ConnTally::default();
    let mut endpoints: Vec<EndpointReport> = opts
        .addrs
        .iter()
        .map(|addr| EndpointReport {
            addr: addr.clone(),
            sent: 0,
            answered: 0,
            rejected: 0,
            connect_retries: 0,
        })
        .collect();
    for (c, tally) in tallies.into_iter().enumerate() {
        let t = tally?;
        total.sent += t.sent;
        total.answered += t.answered;
        total.rejected += t.rejected;
        total.lat_us.extend(t.lat_us);
        total.bitwise_checked += t.bitwise_checked;
        total.bitwise_bad += t.bitwise_bad;
        for v in t.versions {
            total.saw_version(v);
        }
        let ep = &mut endpoints[c % opts.addrs.len()];
        ep.sent += t.sent;
        ep.answered += t.answered;
        ep.rejected += t.rejected;
        ep.connect_retries += t.connect_retries;
    }
    total.lat_us.sort_unstable();
    total.versions.sort_unstable();
    Ok(LoadgenReport {
        sent: total.sent,
        answered: total.answered,
        rejected: total.rejected,
        wall_s,
        qps: total.answered as f64 / wall_s,
        p50_ms: percentile_ms(&total.lat_us, 0.50),
        p95_ms: percentile_ms(&total.lat_us, 0.95),
        p99_ms: percentile_ms(&total.lat_us, 0.99),
        bitwise_checked: total.bitwise_checked,
        bitwise_ok: total.bitwise_bad == 0,
        model_versions: total.versions,
        endpoints,
    })
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TrainConfig;
    use crate::estimators::Estimator;
    use crate::util::json::Value;
    use std::io::Write;

    fn test_model(d: usize, seed: u64) -> Arc<ServeModel> {
        let mlp = Mlp::init(d, &mut Xoshiro256pp::new(seed));
        Arc::new(ServeModel::new(mlp, "sg2", "probe").unwrap())
    }

    fn fast_deadlines() -> Deadlines {
        Deadlines::resolve([Some(5), Some(5), Some(30)], None)
    }

    fn test_opts() -> ServeOpts {
        ServeOpts {
            deadlines: fast_deadlines(),
            threads: 2,
            microbatch: 4,
            queue_cap: 64,
            max_batch: 64,
            metrics_interval: Duration::from_millis(20),
            eval_delay: None,
            reload: None,
            fault: FaultPlan::default(),
        }
    }

    /// Bind loopback, spawn the serve loop for `max_conns` sessions,
    /// return the address and the join handle.
    fn spawn_serve(
        model: Arc<ServeModel>,
        opts: ServeOpts,
        max_conns: usize,
        metrics: Option<MetricsLogger>,
    ) -> (String, std::thread::JoinHandle<Result<()>>) {
        spawn_serve_shared(Arc::new(SharedModel::new(model)), opts, max_conns, metrics)
    }

    /// Like [`spawn_serve`] but keeps the [`SharedModel`] handle with
    /// the caller — the lever the reload tests swap epochs through.
    fn spawn_serve_shared(
        shared: Arc<SharedModel>,
        opts: ServeOpts,
        max_conns: usize,
        metrics: Option<MetricsLogger>,
    ) -> (String, std::thread::JoinHandle<Result<()>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            serve_queries(listener, shared, opts, Some(max_conns), metrics)
        });
        (addr, handle)
    }

    fn points(d: usize, n: usize, seed: u64) -> Vec<f32> {
        random_batch(&mut Xoshiro256pp::new(seed), n, d)
    }

    /// Write a servable training checkpoint with deterministic synthetic
    /// weights: `salt` varies the parameters so two checkpoints of the
    /// same architecture answer with different bits.
    fn write_test_ckpt(path: &Path, d: usize, step: usize, salt: f32) {
        let cfg = TrainConfig {
            family: "sg2".into(),
            method: "probe".into(),
            estimator: Estimator::HteRademacher,
            d,
            v: 4,
            epochs: 100,
            lr0: 1e-3,
            seed: 7,
            lambda_g: 0.0,
            log_every: 10,
        };
        let n = Mlp::n_params_for(d);
        let mut state = vec![0.0f32; 3 * n + 1];
        for (i, s) in state[..n].iter_mut().enumerate() {
            *s = (salt + i as f32 * 1e-3).sin() * 0.2;
        }
        checkpoint::save(path, &cfg, step, None, &[0.5], &state).unwrap();
    }

    /// The serve-tier forward plan fuses (DESIGN.md §12 Pass E): every
    /// hidden layer becomes one `MatmulBiasTanh` superinstruction and
    /// the output layer a `MatmulBias`, and the fused replay answers
    /// with exactly the bits of the unfused replay.
    #[test]
    fn planned_eval_fuses_and_matches_unfused_bits() {
        use crate::autodiff::{
            force_fuse_mode, force_plan_mode, fuse_mode_guard, plan_mode_guard, FuseMode,
            PlanKey, PlanMode,
        };
        let _pg = plan_mode_guard();
        let _fg = fuse_mode_guard();
        force_plan_mode(PlanMode::On);
        let model = test_model(6, 11);
        let xs = points(6, 9, 3);
        let key = PlanKey {
            op: "mlp-fwd",
            scalar_bits: 0,
            nc: 9,
            v: 0,
            d: 6,
            n_params: model.mlp.n_params(),
        };

        force_fuse_mode(FuseMode::Off);
        let mut plain = Vec::new();
        let mut sc_plain = EvalScratch::default();
        // twice: once to compile, once to replay the cached plan
        model.eval_batch(&xs, 9, &mut plain, &mut sc_plain);
        plain.clear();
        model.eval_batch(&xs, 9, &mut plain, &mut sc_plain);
        let st_plain = sc_plain.tape.plan_stats(&key).expect("unfused serve plan cached");
        assert_eq!(st_plain.fused_mb + st_plain.fused_mbt, 0, "HTE_FUSE=off must not fuse");

        force_fuse_mode(FuseMode::On);
        let mut fused = Vec::new();
        let mut sc_fused = EvalScratch::default();
        model.eval_batch(&xs, 9, &mut fused, &mut sc_fused);
        fused.clear();
        model.eval_batch(&xs, 9, &mut fused, &mut sc_fused);
        let st = sc_fused.tape.plan_stats(&key).expect("fused serve plan cached");
        assert!(st.fused_mbt >= 1, "hidden layers should fuse to MatmulBiasTanh: {st:?}");
        assert!(st.fused_mb >= 1, "output layer should fuse to MatmulBias: {st:?}");

        assert_eq!(plain.len(), fused.len());
        for (a, b) in plain.iter().zip(&fused) {
            assert_eq!(a.to_bits(), b.to_bits(), "serve-path fusion changed answer bits");
        }
    }

    /// End-to-end loopback: served answers are bitwise the local
    /// forward, microbatch boundaries included (microbatch=4, n=9
    /// spans three slices), STATS reflects the traffic, and the
    /// metrics stream leaves parseable snapshot lines.
    #[test]
    fn serve_loopback_answers_match_local_forward_bitwise() {
        let d = 6;
        let model = test_model(d, 42);
        let dir = std::env::temp_dir().join(format!("hte-serve-e2e-{}", std::process::id()));
        let metrics_path = dir.join("serve.jsonl");
        let metrics = MetricsLogger::to_file(&metrics_path).unwrap();
        let (addr, handle) = spawn_serve(Arc::clone(&model), test_opts(), 1, Some(metrics));
        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        assert_eq!(client.max_batch, 64);
        for (i, n) in [1usize, 5, 9].into_iter().enumerate() {
            let xs = points(d, n, 100 + i as u64);
            match client.query(&xs).unwrap() {
                QueryReply::Answer { values, model_version, .. } => {
                    let expected = model.eval(&xs);
                    assert_eq!(values.len(), n);
                    assert_eq!(model_version, 1, "a never-reloaded server answers as v1");
                    for (j, (e, g)) in expected.iter().zip(&values).enumerate() {
                        assert_eq!(e.to_bits(), g.to_bits(), "n={n} point {j} diverged");
                    }
                }
                QueryReply::Rejected(why) => panic!("unsaturated server rejected: {why}"),
            }
        }
        let stats = client.stats().unwrap();
        let parsed = Value::parse(&stats).unwrap();
        assert_eq!(parsed.get("queries").unwrap().as_usize().unwrap(), 3);
        assert_eq!(parsed.get("points").unwrap().as_usize().unwrap(), 15);
        assert_eq!(parsed.get("rejected").unwrap().as_usize().unwrap(), 0);
        assert_eq!(parsed.get("model_version").unwrap().as_usize().unwrap(), 1);
        drop(client);
        handle.join().unwrap().unwrap();
        let text = std::fs::read_to_string(&metrics_path).unwrap();
        let last = text.trim().lines().last().expect("metrics stream left no snapshot");
        let snap = Value::parse(last).unwrap();
        assert_eq!(snap.get("queries").unwrap().as_usize().unwrap(), 3);
        assert!(snap.get("qps").unwrap().as_f64().unwrap() > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A mismatched HELLO is rejected during the handshake with the
    /// offending field named — wrong d and wrong family both.
    #[test]
    fn serve_rejects_mismatched_hello_by_name() {
        let model = test_model(6, 43);
        let (addr, handle) = spawn_serve(Arc::clone(&model), test_opts(), 2, None);
        let dl = fast_deadlines();
        // wrong d: the client constructor itself surfaces the server error
        let err = ServeClient::connect(&addr, 8, &dl).unwrap_err().to_string();
        assert!(err.contains("d=8"), "{err}");
        assert!(err.contains("d=6"), "{err}");
        // wrong family, right dims: hand-rolled hello
        let spec = JobSpec {
            family: "bihar".into(),
            method: String::new(),
            lambda_g: 0.0,
            d: 6,
            n_params: Mlp::n_params_for(6),
        };
        let mut stream = connect_worker(&addr, dl.connect).unwrap();
        stream.set_read_timeout(Some(dl.handshake)).ok();
        write_frame(&mut stream, TAG_HELLO, &encode_hello(&spec)).unwrap();
        let (tag, payload) = read_frame(&mut stream).unwrap();
        assert_eq!(tag, TAG_ERROR);
        let msg = Dec::new(&payload).str().unwrap().to_string();
        assert!(msg.contains("bihar"), "{msg}");
        assert!(msg.contains("sg2"), "{msg}");
        drop(stream);
        handle.join().unwrap().unwrap();
    }

    /// Protocol violations are fatal to the connection: garbage magic,
    /// an absurd length word, and a mis-sized query payload each drop
    /// the session (the last one with a named ERROR first).
    #[test]
    fn serve_drops_malformed_and_oversized_frames() {
        let d = 4;
        let model = test_model(d, 44);
        let (addr, handle) = spawn_serve(Arc::clone(&model), test_opts(), 3, None);
        let dl = fast_deadlines();
        // 1: garbage magic after a good handshake
        {
            let mut client = ServeClient::connect(&addr, d, &dl).unwrap();
            let mut head = [0u8; 13];
            head[..4].copy_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
            head[4] = TAG_QUERY;
            client.stream.write_all(&head).unwrap();
            client.stream.flush().unwrap();
            // server drops us: the next read sees EOF (an error)
            assert!(client.read_reply().is_err());
        }
        // 2: absurd length word (> MAX_FRAME)
        {
            let mut client = ServeClient::connect(&addr, d, &dl).unwrap();
            let mut head = Vec::new();
            head.extend_from_slice(&super::super::cluster::FRAME_MAGIC.to_le_bytes());
            head.push(TAG_QUERY);
            head.extend_from_slice(&(u64::MAX).to_le_bytes());
            client.stream.write_all(&head).unwrap();
            client.stream.flush().unwrap();
            assert!(client.read_reply().is_err());
        }
        // 3: query claiming n=3 but shipping 2 points
        {
            let mut client = ServeClient::connect(&addr, d, &dl).unwrap();
            let mut e = Enc::default();
            e.u64(0);
            e.u64(3);
            e.f32s(&points(d, 2, 7));
            write_frame(&mut client.stream, TAG_QUERY, &e.buf).unwrap();
            let err = client.read_reply().unwrap_err().to_string();
            assert!(err.contains("claims n=3"), "{err}");
        }
        handle.join().unwrap().unwrap();
    }

    /// Saturation is answered, not dropped: with one slow evaluator
    /// and a one-deep queue, a burst of pipelined queries gets a
    /// status-1 rejection for the overflow and bit-exact answers for
    /// the rest — every id accounted for, connection still usable.
    #[test]
    fn serve_saturation_rejects_gracefully_and_answers_the_rest() {
        let d = 4;
        let model = test_model(d, 45);
        let opts = ServeOpts {
            threads: 1,
            queue_cap: 1,
            eval_delay: Some(Duration::from_millis(50)),
            ..test_opts()
        };
        let (addr, handle) = spawn_serve(Arc::clone(&model), opts, 1, None);
        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        let total = 10usize;
        let mut batches = Vec::new();
        for i in 0..total {
            let xs = points(d, 2, 200 + i as u64);
            let id = client.send_query(&xs).unwrap();
            batches.push((id, xs));
        }
        let (mut answered, mut rejected) = (0usize, 0usize);
        for _ in 0..total {
            let (id, reply) = client.read_reply().unwrap();
            let (_, xs) = batches.iter().find(|(b, _)| *b == id).expect("unknown id");
            match reply {
                QueryReply::Answer { values, .. } => {
                    answered += 1;
                    let expected = model.eval(xs);
                    assert!(bits_match(&expected, &values), "answer {id} diverged");
                }
                QueryReply::Rejected(why) => {
                    rejected += 1;
                    assert!(why.contains("saturated"), "{why}");
                }
            }
        }
        assert!(rejected >= 1, "a 1-deep queue under a 10-query burst must reject");
        assert!(answered >= 1, "the queued query must still answer");
        assert_eq!(answered + rejected, total);
        // the connection survived saturation: one more round trip works
        let xs = points(d, 1, 999);
        match client.query(&xs).unwrap() {
            QueryReply::Answer { values, .. } => assert!(bits_match(&model.eval(&xs), &values)),
            QueryReply::Rejected(why) => panic!("post-saturation query rejected: {why}"),
        }
        drop(client);
        handle.join().unwrap().unwrap();
    }

    /// A connected-but-stalled client (half a frame header, then
    /// silence) is shed by the handshake deadline and cannot wedge the
    /// server: a well-behaved client connecting afterwards is served.
    #[test]
    fn serve_sheds_stalled_client_by_deadline() {
        let d = 4;
        let model = test_model(d, 46);
        let opts = ServeOpts {
            deadlines: Deadlines::resolve([Some(2), Some(1), Some(5)], None),
            ..test_opts()
        };
        let (addr, handle) = spawn_serve(Arc::clone(&model), opts, 2, None);
        // the staller: half a header, then nothing
        let mut staller = connect_worker(&addr, Duration::from_secs(2)).unwrap();
        staller.write_all(&[0x50, 0x45, 0x54, 0x48, TAG_HELLO]).unwrap();
        staller.flush().unwrap();
        // a healthy client right behind it is served normally
        let dl = Deadlines::resolve([Some(2), Some(5), Some(5)], None);
        let mut client = ServeClient::connect(&addr, d, &dl).unwrap();
        let xs = points(d, 3, 300);
        match client.query(&xs).unwrap() {
            QueryReply::Answer { values, .. } => assert!(bits_match(&model.eval(&xs), &values)),
            QueryReply::Rejected(why) => panic!("rejected: {why}"),
        }
        drop(client);
        drop(staller); // the deadline has long since shed it server-side
        handle.join().unwrap().unwrap();
    }

    /// Closed-loop loadgen: every request answered, every answer
    /// bitwise-verified, throughput measured.
    #[test]
    fn serve_loadgen_closed_loop_is_bitwise_clean() {
        let d = 5;
        let model = test_model(d, 47);
        let (addr, handle) = spawn_serve(Arc::clone(&model), test_opts(), 2, None);
        let opts = LoadgenOpts {
            addrs: vec![addr],
            d,
            arrival: Arrival::Closed,
            rate: 0.0,
            conns: 2,
            batch: 3,
            requests: 8,
            seed: 9,
            deadlines: fast_deadlines(),
        };
        let report = run_loadgen(&opts, Some(&model)).unwrap();
        assert_eq!(report.sent, 8);
        assert_eq!(report.answered, 8);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.bitwise_checked, 8);
        assert!(report.bitwise_ok, "served bits diverged from the local forward");
        assert!(report.qps > 0.0);
        assert_eq!(report.model_versions, vec![1]);
        assert_eq!(report.endpoints.len(), 1);
        assert_eq!(report.endpoints[0].sent, 8);
        assert_eq!(report.endpoints[0].answered, 8);
        assert_eq!(report.endpoints[0].connect_retries, 0);
        handle.join().unwrap().unwrap();
        // the report serializes to parseable JSON
        let parsed = Value::parse(&report.to_json()).unwrap();
        assert_eq!(parsed.get("answered").unwrap().as_usize().unwrap(), 8);
        assert!(matches!(parsed.get("bitwise_ok").unwrap(), Value::Bool(true)));
        let eps = parsed.get("endpoints").unwrap().as_arr().unwrap();
        assert_eq!(eps[0].get("sent").unwrap().as_usize().unwrap(), 8);
    }

    /// Open-loop loadgen: paced arrivals with pipelined out-of-order
    /// replies — every query accounted for (answered or rejected) and
    /// every answer bitwise-verified.
    #[test]
    fn serve_loadgen_open_loop_accounts_for_every_query() {
        let d = 5;
        let model = test_model(d, 48);
        let (addr, handle) = spawn_serve(Arc::clone(&model), test_opts(), 2, None);
        let opts = LoadgenOpts {
            addrs: vec![addr],
            d,
            arrival: Arrival::Open,
            rate: 400.0,
            conns: 2,
            batch: 2,
            requests: 12,
            seed: 10,
            deadlines: fast_deadlines(),
        };
        let report = run_loadgen(&opts, Some(&model)).unwrap();
        assert_eq!(report.sent, 12);
        assert_eq!(report.answered + report.rejected, 12);
        assert_eq!(report.bitwise_checked, report.answered);
        assert!(report.bitwise_ok, "served bits diverged from the local forward");
        // per-endpoint accounting covers every query of the run
        assert_eq!(report.endpoints.iter().map(|e| e.sent).sum::<usize>(), 12);
        handle.join().unwrap().unwrap();
    }

    /// Percentiles and snapshot serialization: known latencies come
    /// back at the right ranks, and the JSON parses.
    #[test]
    fn serve_snapshot_percentiles_and_json() {
        let stats = ServeStats::new();
        for ms in 1..=100u64 {
            stats.record_answer(4, Duration::from_millis(ms));
        }
        stats.record_rejection();
        let snap = stats.snapshot(3, 2, 450);
        assert_eq!(snap.queries, 100);
        assert_eq!(snap.points, 400);
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.queue_depth, 3);
        assert_eq!(snap.model_version, 2);
        assert_eq!(snap.ckpt_step, 450);
        assert!((snap.p50_ms - 50.0).abs() <= 1.0, "p50 {}", snap.p50_ms);
        assert!((snap.p95_ms - 95.0).abs() <= 1.0, "p95 {}", snap.p95_ms);
        assert!((snap.p99_ms - 99.0).abs() <= 1.0, "p99 {}", snap.p99_ms);
        let parsed = Value::parse(&snap.to_json()).unwrap();
        assert_eq!(parsed.get("queries").unwrap().as_usize().unwrap(), 100);
        assert_eq!(parsed.get("queue_depth").unwrap().as_usize().unwrap(), 3);
        assert_eq!(parsed.get("model_version").unwrap().as_usize().unwrap(), 2);
        assert_eq!(parsed.get("ckpt_step").unwrap().as_usize().unwrap(), 450);
        // empty stats: percentiles are 0, not NaN/panic
        assert_eq!(percentile_ms(&[], 0.99), 0.0);
    }

    /// The reload gate, in-process: one unbroken connection is answered
    /// by checkpoint A as model_version 1, the epoch hot-swaps to
    /// checkpoint B, and the *same* connection is answered by B as
    /// version 2 — each answer bitwise its own checkpoint's local
    /// forward, and the stats snapshot stays monotonic through the swap
    /// (a client that saw k answers can never read a snapshot
    /// undercounting them).
    #[test]
    fn serve_reload_hot_swaps_without_dropping_the_connection() {
        let d = 4;
        let dir = std::env::temp_dir().join(format!("hte-serve-reload-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt_a = dir.join("a.ckpt");
        let ckpt_b = dir.join("b.ckpt");
        write_test_ckpt(&ckpt_a, d, 100, 0.25);
        write_test_ckpt(&ckpt_b, d, 200, -0.75);
        let model_a = Arc::new(ServeModel::from_checkpoint(&ckpt_a).unwrap());
        let model_b = ServeModel::from_checkpoint(&ckpt_b).unwrap();
        let shared = Arc::new(SharedModel::new(Arc::clone(&model_a)));
        let (addr, handle) = spawn_serve_shared(Arc::clone(&shared), test_opts(), 1, None);
        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        let xs = points(d, 3, 500);
        match client.query(&xs).unwrap() {
            QueryReply::Answer { values, model_version, ckpt_step } => {
                assert_eq!(model_version, 1);
                assert_eq!(ckpt_step, 100);
                assert!(bits_match(&model_a.eval(&xs), &values), "v1 answer diverged from A");
            }
            QueryReply::Rejected(why) => panic!("rejected: {why}"),
        }
        let ep = shared.reload_from(&ckpt_b).unwrap();
        assert_eq!(ep.version, 2);
        // two checkpoints with different weights must answer differently
        assert!(!bits_match(&model_a.eval(&xs), &model_b.eval(&xs)));
        match client.query(&xs).unwrap() {
            QueryReply::Answer { values, model_version, ckpt_step } => {
                assert_eq!(model_version, 2, "post-swap answer still stamped v1");
                assert_eq!(ckpt_step, 200);
                assert!(bits_match(&model_b.eval(&xs), &values), "v2 answer diverged from B");
            }
            QueryReply::Rejected(why) => panic!("rejected: {why}"),
        }
        // stats monotonicity across the swap: the client has seen 2
        // answers, so the snapshot counts >= 2 and queries == answered
        // (+ rejected == 0), stamped with the new version
        let parsed = Value::parse(&client.stats().unwrap()).unwrap();
        assert!(parsed.get("queries").unwrap().as_usize().unwrap() >= 2);
        assert_eq!(parsed.get("rejected").unwrap().as_usize().unwrap(), 0);
        assert_eq!(parsed.get("model_version").unwrap().as_usize().unwrap(), 2);
        assert_eq!(parsed.get("ckpt_step").unwrap().as_usize().unwrap(), 200);
        drop(client);
        handle.join().unwrap().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Reload validation: a checkpoint with the wrong dimension and a
    /// bit-flipped checkpoint are both rejected by name, and the old
    /// model keeps serving the *same* connection afterwards.
    #[test]
    fn serve_reload_rejects_bad_checkpoints_and_keeps_serving() {
        let d = 4;
        let dir =
            std::env::temp_dir().join(format!("hte-serve-reload-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt_a = dir.join("a.ckpt");
        let ckpt_wrong_d = dir.join("wrong_d.ckpt");
        let ckpt_corrupt = dir.join("corrupt.ckpt");
        write_test_ckpt(&ckpt_a, d, 100, 0.25);
        write_test_ckpt(&ckpt_wrong_d, 6, 100, 0.25);
        write_test_ckpt(&ckpt_corrupt, d, 300, 0.5);
        // flip one payload bit: same length, valid header, broken CRC
        let mut bytes = std::fs::read(&ckpt_corrupt).unwrap();
        let at = bytes.len() - 40;
        bytes[at] ^= 0x08;
        std::fs::write(&ckpt_corrupt, &bytes).unwrap();
        let model_a = Arc::new(ServeModel::from_checkpoint(&ckpt_a).unwrap());
        let shared = Arc::new(SharedModel::new(Arc::clone(&model_a)));
        let (addr, handle) = spawn_serve_shared(Arc::clone(&shared), test_opts(), 1, None);
        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        let err = shared.reload_from(&ckpt_wrong_d).unwrap_err().to_string();
        assert!(err.contains("d=6"), "{err}");
        assert!(err.contains("d=4"), "{err}");
        let err = format!("{:#}", shared.reload_from(&ckpt_corrupt).unwrap_err());
        assert!(err.contains("checksum"), "{err}");
        // both rejections left epoch 1 serving, connection intact
        let xs = points(d, 2, 600);
        match client.query(&xs).unwrap() {
            QueryReply::Answer { values, model_version, .. } => {
                assert_eq!(model_version, 1);
                assert!(bits_match(&model_a.eval(&xs), &values));
            }
            QueryReply::Rejected(why) => panic!("rejected: {why}"),
        }
        drop(client);
        handle.join().unwrap().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The `--watch` trigger end to end, in-process: replacing the
    /// watched file swaps the epoch without any client action.
    #[test]
    fn serve_reload_watch_follows_the_checkpoint_file() {
        let d = 4;
        let dir =
            std::env::temp_dir().join(format!("hte-serve-reload-watch-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let watched = dir.join("live.ckpt");
        write_test_ckpt(&watched, d, 100, 0.25);
        let model_a = Arc::new(ServeModel::from_checkpoint(&watched).unwrap());
        let shared = Arc::new(SharedModel::new(Arc::clone(&model_a)));
        let opts = ServeOpts {
            reload: Some(ReloadPlan {
                path: watched.clone(),
                on_sighup: false,
                watch: true,
                poll: Duration::from_millis(10),
            }),
            ..test_opts()
        };
        let (addr, handle) = spawn_serve_shared(Arc::clone(&shared), opts, 1, None);
        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        let xs = points(d, 2, 700);
        match client.query(&xs).unwrap() {
            QueryReply::Answer { model_version, .. } => assert_eq!(model_version, 1),
            QueryReply::Rejected(why) => panic!("rejected: {why}"),
        }
        // overwrite the watched file with new weights (atomic-rename
        // save, so the watcher never sees a torn file), wait for the
        // reloader to pick it up
        std::thread::sleep(Duration::from_millis(50));
        write_test_ckpt(&watched, d, 200, -0.75);
        let model_b = ServeModel::from_checkpoint(&watched).unwrap();
        let mut swapped = false;
        for _ in 0..300 {
            if shared.current().version >= 2 {
                swapped = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(swapped, "the watcher never reloaded the replaced checkpoint");
        match client.query(&xs).unwrap() {
            QueryReply::Answer { values, model_version, ckpt_step } => {
                assert_eq!(model_version, 2);
                assert_eq!(ckpt_step, 200);
                assert!(bits_match(&model_b.eval(&xs), &values));
            }
            QueryReply::Rejected(why) => panic!("rejected: {why}"),
        }
        drop(client);
        handle.join().unwrap().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The SIGHUP latch: a real `kill -HUP` to this process flips the
    /// flag exactly once (the reload path for `--reload-on sighup`).
    #[cfg(unix)]
    #[test]
    fn serve_reload_sighup_latch_catches_a_real_signal() {
        sighup::install();
        sighup::take_pending(); // clear anything stale
        let status = std::process::Command::new("kill")
            .args(["-HUP", &std::process::id().to_string()])
            .status()
            .expect("spawning kill");
        assert!(status.success());
        let mut seen = false;
        for _ in 0..200 {
            if sighup::take_pending() {
                seen = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(seen, "SIGHUP latch never set");
        // latched once: the take cleared it
        assert!(!sighup::take_pending());
    }

    /// Serve-phase chaos clause `die_after_queries`: the first query is
    /// answered bit-exact, the budget then kills the connection, and the
    /// replica stays dead for later connections too (a black hole that
    /// handshakes but never answers — what the router must eject).
    #[test]
    fn serve_chaos_die_after_queries_blackholes_the_replica() {
        let d = 4;
        let model = test_model(d, 49);
        let opts = ServeOpts {
            fault: FaultPlan::parse("die_after_queries=1").unwrap(),
            ..test_opts()
        };
        let (addr, handle) = spawn_serve(Arc::clone(&model), opts, 2, None);
        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        let xs = points(d, 2, 800);
        match client.query(&xs).unwrap() {
            QueryReply::Answer { values, .. } => {
                assert!(bits_match(&model.eval(&xs), &values));
            }
            QueryReply::Rejected(why) => panic!("rejected: {why}"),
        }
        // the second query exceeds the budget: the connection drops
        assert!(client.query(&xs).is_err());
        // a fresh connection handshakes but dies on its first query
        let mut second = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        assert!(second.query(&xs).is_err());
        drop(client);
        drop(second);
        handle.join().unwrap().unwrap();
    }
}
