//! `hte-pinn router`: a replicated serving front end with failover
//! (DESIGN.md §13).
//!
//! The router speaks the serve wire protocol on *both* sides.  Clients
//! dial it exactly like a lone serve process — same HELLO/HELLO_ACK
//! handshake, same `QUERY`/`ANSWER`/`STATS` tags — and behind it a pool
//! of replica serve processes answers the actual queries.  Because a
//! served answer is bitwise the local forward for the same checkpoint
//! (DESIGN.md §11), any replica's answer is interchangeable with any
//! other's, which makes transparent retry *semantically free*: a query
//! that dies with one replica is re-sent to a survivor and the client
//! never learns.
//!
//! What is retried and what is not, precisely:
//!
//! - **Transport failures** (connect refused, read/write error, frame
//!   desync, deadline shed) are retried on the next replica in
//!   round-robin order.  The failing replica's connection is dropped
//!   and its consecutive-failure count bumped.
//! - **Saturation/oversize rejections** are *not* retried.  They are
//!   the replica's backpressure signal; re-sending an already-rejected
//!   query to its neighbor amplifies exactly the overload that caused
//!   the rejection.  The rejection frame is relayed to the client
//!   verbatim and counted separately (`saturated`).
//!
//! Replica health is a small state machine per replica:
//!
//! ```text
//!           round trip ok            failure
//!   LIVE ------------------> LIVE  ----------> LIVE (conn dropped,
//!     ^                                         re-dial after backoff)
//!     |  handshake ok                  | consecutive_failures
//!     |  (rejoins += 1)                v reaches eject_after
//!   EJECTED <------------------------ (ejections += 1)
//!     (re-dial every max(rejoin_interval, backoff))
//! ```
//!
//! Re-dial backoff reuses the cluster's bounded-exponential machinery
//! with deterministic per-address jitter ([`backoff_delay`] /
//! [`addr_salt`]), so a pool of routers hammering the same dead replica
//! staggers its retries reproducibly.  A rejoining replica's ACK is
//! re-validated against the agreed spec — a replica restarted with a
//! different checkpoint family or architecture is named and kept out.
//!
//! Answers are relayed as **raw payload bytes** — the router never
//! re-encodes an answer it forwards, so the bits a client sees are the
//! bits the replica produced (the `model_version`/`ckpt_step` stamps
//! ride along untouched).  The only answers the router mints itself are
//! "no live replicas" rejections, stamped `model_version 0` because no
//! model produced them.
//!
//! Accounting invariant, checked by the chaos suite: every query is
//! counted exactly once — `queries == answered + rejected`, where
//! `rejected` = relayed replica rejections + router-local "no live
//! replicas" rejections.  `retried` and `saturated` are diagnostic
//! overlays, not part of the partition.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::cluster::{
    addr_salt, backoff_delay, env_secs, read_frame, read_frame_or_eof, send_error, write_frame,
    Deadlines, Dec, Enc, JobSpec, TAG_ANSWER, TAG_HELLO, TAG_HELLO_ACK, TAG_QUERY, TAG_STATS,
};
use super::serve::{check_hello, encode_answer_rejected, ServeClient, ANSWER_REJECTED};

/// Router configuration.  [`RouterOpts::new`] gives the CLI defaults;
/// tests shrink the knobs for speed.
#[derive(Clone, Copy, Debug)]
pub struct RouterOpts {
    pub deadlines: Deadlines,
    /// Input dimension the replicas must serve (fixes `n_params` too —
    /// the architecture is a function of `d`).
    pub d: usize,
    /// Consecutive round-trip failures before a replica is ejected.
    pub eject_after: u32,
    /// Minimum interval between re-dial attempts at an *ejected*
    /// replica (a merely-disconnected one retries on the shorter
    /// failure backoff).
    pub rejoin_interval: Duration,
}

impl RouterOpts {
    /// Defaults: deadlines from the environment, eject after 3
    /// consecutive failures, probe ejected replicas every 5 seconds
    /// (override with `HTE_REJOIN_INTERVAL_SECS`).
    pub fn new(d: usize) -> Self {
        RouterOpts {
            deadlines: Deadlines::from_env(),
            d,
            eject_after: 3,
            rejoin_interval: Duration::from_secs(
                env_secs("HTE_REJOIN_INTERVAL_SECS").unwrap_or(5).max(1),
            ),
        }
    }
}

/// Mutable half of a replica: the (single, shared) connection plus the
/// failure streak that drives ejection.  Held under a mutex — a round
/// trip owns the connection end to end, so answers can never
/// interleave and id-matching stays trivial.
struct ConnState {
    client: Option<ServeClient>,
    consecutive_failures: u32,
    last_attempt: Option<Instant>,
}

/// One backend serve process, with lifetime counters for the stats
/// snapshot.
struct Replica {
    addr: String,
    /// Deterministic jitter salt for re-dial backoff.
    salt: u64,
    conn: Mutex<ConnState>,
    answered: AtomicU64,
    failures: AtomicU64,
    saturations: AtomicU64,
    /// `false` while ejected (failure streak reached `eject_after`).
    live: AtomicBool,
}

/// Router-level counters.  `queries == answered + rejected` always;
/// `saturated`/`retried`/`ejections`/`rejoins` are diagnostics.
struct RouterStats {
    queries: AtomicU64,
    answered: AtomicU64,
    rejected: AtomicU64,
    saturated: AtomicU64,
    retried: AtomicU64,
    ejections: AtomicU64,
    rejoins: AtomicU64,
    started: Instant,
}

/// Per-replica block of a [`RouterSnapshot`].
#[derive(Clone, Debug)]
pub struct ReplicaSnapshot {
    pub addr: String,
    pub live: bool,
    pub answered: u64,
    pub failures: u64,
    pub saturations: u64,
}

/// The router's observability snapshot, answered on [`TAG_STATS`] as
/// JSON (tagged `"tier":"router"` so dashboards can tell it from a
/// replica snapshot).
#[derive(Clone, Debug)]
pub struct RouterSnapshot {
    pub elapsed_s: f64,
    pub queries: u64,
    pub answered: u64,
    pub rejected: u64,
    pub saturated: u64,
    pub retried: u64,
    pub ejections: u64,
    pub rejoins: u64,
    pub replicas: Vec<ReplicaSnapshot>,
}

impl RouterSnapshot {
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\"tier\":\"router\",\"elapsed_s\":{:.3},\"queries\":{},\"answered\":{},\
             \"rejected\":{},\"saturated\":{},\"retried\":{},\"ejections\":{},\
             \"rejoins\":{},\"replicas\":[",
            self.elapsed_s,
            self.queries,
            self.answered,
            self.rejected,
            self.saturated,
            self.retried,
            self.ejections,
            self.rejoins
        );
        for (i, r) in self.replicas.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"addr\":{:?},\"live\":{},\"answered\":{},\"failures\":{},\
                 \"saturations\":{}}}",
                r.addr, r.live, r.answered, r.failures, r.saturations
            ));
        }
        out.push_str("]}");
        out
    }
}

/// What one replica attempt came back as (internal to `forward`).
enum TryOutcome {
    /// Replica is disconnected and its backoff has not elapsed — no
    /// bytes were sent, this replica simply did not participate.
    Skipped,
    /// Got a well-formed answer with the matching id; `saturated` is
    /// the replica's own rejected status (relayed, never retried).
    Answered { frame: Vec<u8>, saturated: bool },
    /// Transport/protocol failure — the connection was dropped and the
    /// failure recorded; the query may be retried elsewhere.
    Failed,
}

/// The replicated-serving front end: an agreed model spec, a replica
/// pool with per-replica health, and round-robin fan-out with
/// failover.  Shared across client-handler threads behind an `Arc`.
pub struct Router {
    replicas: Vec<Arc<Replica>>,
    /// The spec every replica agreed on at startup (method left empty:
    /// the serve ACK does not carry it, and it is a training-side
    /// concern).  Client HELLOs are validated against this.
    spec: JobSpec,
    /// Smallest `max_batch` any replica advertised — what the router
    /// advertises, so an accepted batch fits every backend.
    max_batch: usize,
    opts: RouterOpts,
    next: AtomicUsize,
    stats: RouterStats,
}

impl Router {
    /// Dial every replica, cross-check that all reachable ones agree on
    /// the served model (family and parameter count, by name — `d` is
    /// already enforced per-connection by the handshake), and build the
    /// pool.  At least one replica must be reachable; unreachable ones
    /// start ejected and are probed for rejoin on the regular schedule.
    pub fn connect(addrs: &[String], opts: RouterOpts) -> Result<Self> {
        if addrs.is_empty() {
            bail!("a router needs at least one replica address");
        }
        let mut clients: Vec<Option<ServeClient>> = Vec::with_capacity(addrs.len());
        for addr in addrs {
            match ServeClient::connect(addr, opts.d, &opts.deadlines) {
                Ok(c) => clients.push(Some(c)),
                Err(e) => {
                    eprintln!(
                        "router: replica {addr} unreachable at startup (will probe for \
                         rejoin): {e:#}"
                    );
                    clients.push(None);
                }
            }
        }
        let first = match clients.iter().position(|c| c.is_some()) {
            Some(i) => i,
            None => bail!(
                "none of the {} replicas are reachable — is the serve tier up?",
                addrs.len()
            ),
        };
        let (agreed_family, agreed_n_params) = {
            let c = clients[first].as_ref().expect("position() found it");
            (c.family.clone(), c.n_params)
        };
        let mut max_batch = usize::MAX;
        for (i, client) in clients.iter().enumerate() {
            let Some(c) = client else { continue };
            if c.family != agreed_family {
                bail!(
                    "replica {} serves family {} but replica {} serves {} — \
                     the pool must serve one model",
                    addrs[i],
                    c.family,
                    addrs[first],
                    agreed_family
                );
            }
            if c.n_params != agreed_n_params {
                bail!(
                    "replica {} serves {} parameters but replica {} serves {} — \
                     mixed checkpoints in the pool",
                    addrs[i],
                    c.n_params,
                    addrs[first],
                    agreed_n_params
                );
            }
            max_batch = max_batch.min(c.max_batch);
        }
        let replicas = addrs
            .iter()
            .zip(clients)
            .map(|(addr, client)| {
                let reachable = client.is_some();
                Arc::new(Replica {
                    addr: addr.clone(),
                    salt: addr_salt(addr),
                    conn: Mutex::new(ConnState {
                        client,
                        // unreachable slots start at the ejection
                        // threshold: probed on the rejoin schedule, not
                        // the hot failure backoff
                        consecutive_failures: if reachable { 0 } else { opts.eject_after },
                        last_attempt: Some(Instant::now()),
                    }),
                    answered: AtomicU64::new(0),
                    failures: AtomicU64::new(0),
                    saturations: AtomicU64::new(0),
                    live: AtomicBool::new(reachable),
                })
            })
            .collect();
        Ok(Router {
            replicas,
            spec: JobSpec {
                family: agreed_family,
                method: String::new(),
                lambda_g: 0.0,
                d: opts.d,
                n_params: agreed_n_params,
            },
            max_batch,
            opts,
            next: AtomicUsize::new(0),
            stats: RouterStats {
                queries: AtomicU64::new(0),
                answered: AtomicU64::new(0),
                rejected: AtomicU64::new(0),
                saturated: AtomicU64::new(0),
                retried: AtomicU64::new(0),
                ejections: AtomicU64::new(0),
                rejoins: AtomicU64::new(0),
                started: Instant::now(),
            },
        })
    }

    /// The spec the pool agreed on (what client HELLOs are checked
    /// against).
    pub fn spec(&self) -> &JobSpec {
        &self.spec
    }

    /// Largest batch the router accepts (the pool minimum).
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Replicas currently live (not ejected).
    pub fn live_replicas(&self) -> usize {
        self.replicas.iter().filter(|r| r.live.load(Ordering::Acquire)).count()
    }

    pub fn snapshot(&self) -> RouterSnapshot {
        RouterSnapshot {
            elapsed_s: self.stats.started.elapsed().as_secs_f64(),
            queries: self.stats.queries.load(Ordering::Relaxed),
            answered: self.stats.answered.load(Ordering::Relaxed),
            rejected: self.stats.rejected.load(Ordering::Relaxed),
            saturated: self.stats.saturated.load(Ordering::Relaxed),
            retried: self.stats.retried.load(Ordering::Relaxed),
            ejections: self.stats.ejections.load(Ordering::Relaxed),
            rejoins: self.stats.rejoins.load(Ordering::Relaxed),
            replicas: self
                .replicas
                .iter()
                .map(|r| ReplicaSnapshot {
                    addr: r.addr.clone(),
                    live: r.live.load(Ordering::Acquire),
                    answered: r.answered.load(Ordering::Relaxed),
                    failures: r.failures.load(Ordering::Relaxed),
                    saturations: r.saturations.load(Ordering::Relaxed),
                })
                .collect(),
        }
    }

    /// Route one validated QUERY payload and return the ANSWER payload
    /// to relay.  Counts the query exactly once: answered (replica
    /// evaluated it), rejected (replica rejection relayed, or no live
    /// replica was left to ask).
    pub fn forward(&self, payload: &[u8]) -> Vec<u8> {
        self.stats.queries.fetch_add(1, Ordering::Relaxed);
        let id = Dec::new(payload).u64().unwrap_or(0);
        let n = self.replicas.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        let mut attempted = 0u32;
        for k in 0..n {
            let replica = &self.replicas[(start + k) % n];
            let outcome = self.try_replica(replica, payload);
            if matches!(outcome, TryOutcome::Skipped) {
                continue;
            }
            attempted += 1;
            if attempted > 1 {
                // a re-send of a query some replica already failed —
                // safe because answers are bitwise interchangeable
                self.stats.retried.fetch_add(1, Ordering::Relaxed);
            }
            if let TryOutcome::Answered { frame, saturated } = outcome {
                if saturated {
                    replica.saturations.fetch_add(1, Ordering::Relaxed);
                    self.stats.saturated.fetch_add(1, Ordering::Relaxed);
                    self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                } else {
                    replica.answered.fetch_add(1, Ordering::Relaxed);
                    self.stats.answered.fetch_add(1, Ordering::Relaxed);
                }
                return frame;
            }
        }
        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
        encode_answer_rejected(
            id,
            &format!(
                "no live replicas — all {} backends are down or backing off; retry shortly",
                n
            ),
            0, // minted by the router, no model produced it
            0,
        )
    }

    /// One attempt at one replica: re-dial if disconnected and due,
    /// then a blocking QUERY/ANSWER round trip holding the connection
    /// lock (so concurrent client queries to the same replica serialize
    /// and answers cannot interleave).
    fn try_replica(&self, replica: &Replica, payload: &[u8]) -> TryOutcome {
        let mut conn = replica.conn.lock().expect("replica conn lock poisoned");
        if conn.client.is_none() {
            let ejected = conn.consecutive_failures >= self.opts.eject_after;
            let mut wait = backoff_delay(conn.consecutive_failures, replica.salt);
            if ejected {
                wait = wait.max(self.opts.rejoin_interval);
            }
            if let Some(t) = conn.last_attempt {
                if t.elapsed() < wait {
                    return TryOutcome::Skipped;
                }
            }
            conn.last_attempt = Some(Instant::now());
            match ServeClient::connect(&replica.addr, self.opts.d, &self.opts.deadlines) {
                Ok(client) => {
                    if client.family != self.spec.family || client.n_params != self.spec.n_params {
                        eprintln!(
                            "router: replica {} came back serving {}/{} params but the pool \
                             agreed on {}/{} params — keeping it out",
                            replica.addr,
                            client.family,
                            client.n_params,
                            self.spec.family,
                            self.spec.n_params
                        );
                        self.record_failure(replica, &mut conn);
                        return TryOutcome::Failed;
                    }
                    conn.client = Some(client);
                    conn.consecutive_failures = 0;
                    replica.live.store(true, Ordering::Release);
                    if ejected {
                        self.stats.rejoins.fetch_add(1, Ordering::Relaxed);
                        eprintln!("router: replica {} rejoined the pool", replica.addr);
                    }
                }
                Err(e) => {
                    eprintln!("router: re-dial of replica {} failed: {e:#}", replica.addr);
                    self.record_failure(replica, &mut conn);
                    return TryOutcome::Failed;
                }
            }
        }
        let client = conn.client.as_mut().expect("connected above");
        match round_trip(client, payload) {
            Ok((frame, status)) => {
                conn.consecutive_failures = 0;
                TryOutcome::Answered { frame, saturated: status == ANSWER_REJECTED }
            }
            Err(e) => {
                eprintln!(
                    "router: query round trip with replica {} failed: {e:#}",
                    replica.addr
                );
                // drop the connection whole: a half-read stream can
                // hold stale frames, and a fresh dial resynchronizes
                conn.client = None;
                conn.last_attempt = Some(Instant::now());
                self.record_failure(replica, &mut conn);
                TryOutcome::Failed
            }
        }
    }

    fn record_failure(&self, replica: &Replica, conn: &mut ConnState) {
        replica.failures.fetch_add(1, Ordering::Relaxed);
        conn.consecutive_failures = conn.consecutive_failures.saturating_add(1);
        if conn.consecutive_failures == self.opts.eject_after {
            replica.live.store(false, Ordering::Release);
            self.stats.ejections.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "router: ejecting replica {} after {} consecutive failures",
                replica.addr, conn.consecutive_failures
            );
        }
    }
}

/// One QUERY/ANSWER round trip on an established replica connection.
/// Returns the raw answer payload (relayed bit-for-bit) plus its
/// decoded status word.  Any protocol surprise — wrong tag, id
/// mismatch, truncated frame — is a failure, and the caller drops the
/// connection.
fn round_trip(client: &mut ServeClient, payload: &[u8]) -> Result<(Vec<u8>, u32)> {
    let id = Dec::new(payload).u64().context("reading the query id")?;
    write_frame(&mut client.stream, TAG_QUERY, payload).context("relaying the query")?;
    let (tag, answer) = read_frame(&mut client.stream).context("waiting for the answer")?;
    if tag != TAG_ANSWER {
        bail!("replica sent frame tag {tag} where an answer was expected");
    }
    let mut dec = Dec::new(&answer);
    let got = dec.u64()?;
    if got != id {
        bail!("replica answered id {got} for query id {id} — stream desynchronized");
    }
    let status = dec.u32()?;
    Ok((answer, status))
}

/// One client session at the router: the serve handshake (validated
/// against the pool's agreed spec, acked as a `"serve"` tier so
/// clients cannot tell a router from a lone replica), then pipelined
/// QUERY/STATS frames.  Malformed queries are fatal to the connection
/// — same contract as a replica — and are *not* forwarded, so a bad
/// client cannot burn backend connections.
fn handle_router_client(mut stream: TcpStream, router: &Router) -> Result<()> {
    let dl = router.opts.deadlines;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(dl.handshake)).ok();
    stream.set_write_timeout(Some(dl.handshake)).ok();
    let Some((tag, payload)) = read_frame_or_eof(&mut stream)? else {
        return Ok(()); // connected and left without a word
    };
    if tag != TAG_HELLO {
        let _ = send_error(&mut stream, "expected a hello frame");
        bail!("expected a hello frame, got tag {tag}");
    }
    if let Err(e) = check_hello(&payload, &router.spec) {
        let _ = send_error(&mut stream, &format!("{e:#}"));
        return Err(e);
    }
    let mut ack = Enc::default();
    ack.str("serve");
    ack.str(&router.spec.family);
    ack.u64(router.spec.d as u64);
    ack.u64(router.spec.n_params as u64);
    ack.u64(router.max_batch as u64);
    write_frame(&mut stream, TAG_HELLO_ACK, &ack.buf).context("sending the router ack")?;
    stream.set_read_timeout(Some(dl.step)).ok();
    stream.set_write_timeout(Some(dl.step)).ok();
    let d = router.spec.d;
    let mut xs_scratch: Vec<f32> = Vec::new();
    loop {
        let Some((tag, payload)) = read_frame_or_eof(&mut stream)? else {
            return Ok(()); // clean goodbye
        };
        match tag {
            TAG_QUERY => {
                // validate shape before spending a replica on it
                let mut dec = Dec::new(&payload);
                let id = dec.u64()?;
                let n = dec.u64()? as usize;
                xs_scratch.clear();
                dec.f32s_into(&mut xs_scratch)?;
                if xs_scratch.len() != n * d {
                    let msg = format!(
                        "query {id} claims n={n} points at d={d} but ships {} coords",
                        xs_scratch.len()
                    );
                    let _ = send_error(&mut stream, &msg);
                    bail!("{msg}");
                }
                let answer = router.forward(&payload);
                write_frame(&mut stream, TAG_ANSWER, &answer).context("relaying the answer")?;
            }
            TAG_STATS => {
                let mut e = Enc::default();
                e.str(&router.snapshot().to_json());
                write_frame(&mut stream, TAG_STATS, &e.buf).context("answering stats")?;
            }
            other => {
                let _ = send_error(&mut stream, &format!("unexpected frame tag {other}"));
                bail!("unexpected frame tag {other}");
            }
        }
    }
}

/// The router accept loop: one handler thread per client connection,
/// all sharing the [`Router`] (and therefore the replica pool and its
/// health state).  `max_conns: Some(k)` accepts exactly `k` sessions
/// and joins them — the test shape; `None` serves forever (the CLI).
pub fn serve_router(
    listener: TcpListener,
    router: Arc<Router>,
    max_conns: Option<usize>,
) -> Result<()> {
    let mut handlers = Vec::new();
    let mut served = 0usize;
    for stream in listener.incoming() {
        let stream = stream.context("accepting a router connection")?;
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
        let router = Arc::clone(&router);
        let handle = std::thread::spawn(move || {
            if let Err(e) = handle_router_client(stream, &router) {
                eprintln!("router: session with {peer} ended with an error: {e:#}");
            }
        });
        if max_conns.is_some() {
            handlers.push(handle);
        }
        served += 1;
        if let Some(max) = max_conns {
            if served >= max {
                break;
            }
        }
    }
    for h in handlers {
        let _ = h.join();
    }
    Ok(())
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::super::fault::FaultPlan;
    use super::super::serve::{serve_queries, QueryReply, ServeModel, ServeOpts, SharedModel};
    use super::*;
    use crate::nn::Mlp;
    use crate::rng::Xoshiro256pp;
    use crate::util::json::Value;

    fn fast_deadlines() -> Deadlines {
        Deadlines::resolve([Some(5), Some(5), Some(30)], None)
    }

    fn test_model(d: usize, seed: u64, family: &str) -> Arc<ServeModel> {
        let mlp = Mlp::init(d, &mut Xoshiro256pp::new(seed));
        Arc::new(ServeModel::new(mlp, family, "probe").unwrap())
    }

    fn replica_opts() -> ServeOpts {
        ServeOpts {
            deadlines: fast_deadlines(),
            threads: 2,
            microbatch: 4,
            queue_cap: 64,
            max_batch: 64,
            metrics_interval: Duration::from_millis(20),
            eval_delay: None,
            reload: None,
            fault: FaultPlan::default(),
        }
    }

    /// Spawn one in-process replica for `max_conns` sessions; returns
    /// its address and join handle.
    fn spawn_replica(
        model: Arc<ServeModel>,
        opts: ServeOpts,
        max_conns: usize,
    ) -> (String, std::thread::JoinHandle<Result<()>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let shared = Arc::new(SharedModel::new(model));
        let handle = std::thread::spawn(move || {
            serve_queries(listener, shared, opts, Some(max_conns), None)
        });
        (addr, handle)
    }

    /// Spawn the router accept loop for `max_conns` client sessions.
    fn spawn_router(
        router: Arc<Router>,
        max_conns: usize,
    ) -> (String, std::thread::JoinHandle<Result<()>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle =
            std::thread::spawn(move || serve_router(listener, router, Some(max_conns)));
        (addr, handle)
    }

    fn test_router_opts(d: usize) -> RouterOpts {
        RouterOpts {
            deadlines: fast_deadlines(),
            d,
            eject_after: 1,
            rejoin_interval: Duration::from_secs(60),
        }
    }

    fn points(d: usize, n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..n * d).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn router_fans_out_bitwise_and_accounts_every_query() {
        let d = 4;
        let model = test_model(d, 42, "sg2");
        let (a1, h1) = spawn_replica(Arc::clone(&model), replica_opts(), 1);
        let (a2, h2) = spawn_replica(Arc::clone(&model), replica_opts(), 1);
        let router = Arc::new(
            Router::connect(&[a1, a2], test_router_opts(d)).expect("router connects"),
        );
        assert_eq!(router.spec().family, "sg2");
        assert_eq!(router.live_replicas(), 2);
        let (addr, hr) = spawn_router(Arc::clone(&router), 1);

        // a client cannot tell the router from a lone serve process
        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        assert_eq!(client.family, "sg2");
        assert_eq!(client.n_params, Mlp::n_params_for(d));
        assert_eq!(client.max_batch, 64);

        let total = 6;
        for q in 0..total {
            let xs = points(d, 3, 100 + q);
            let expect = model.eval(&xs);
            match client.query(&xs).unwrap() {
                QueryReply::Answer { values, model_version, .. } => {
                    assert_eq!(model_version, 1);
                    assert_eq!(values.len(), expect.len());
                    for (got, want) in values.iter().zip(&expect) {
                        assert_eq!(got.to_bits(), want.to_bits(), "answers must be bitwise");
                    }
                }
                other => panic!("expected an answer, got {other:?}"),
            }
        }

        let stats = client.stats().unwrap();
        let parsed = Value::parse(&stats).unwrap();
        assert_eq!(parsed.get("tier").unwrap().as_str().unwrap(), "router");
        assert_eq!(parsed.get("queries").unwrap().as_usize().unwrap(), total as usize);
        assert_eq!(parsed.get("answered").unwrap().as_usize().unwrap(), total as usize);
        assert_eq!(parsed.get("rejected").unwrap().as_usize().unwrap(), 0);
        assert_eq!(parsed.get("retried").unwrap().as_usize().unwrap(), 0);
        let reps = parsed.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(reps.len(), 2);
        let per_replica: usize =
            reps.iter().map(|r| r.get("answered").unwrap().as_usize().unwrap()).sum();
        assert_eq!(per_replica, total as usize, "round-robin must account every answer");
        for r in reps {
            assert_eq!(r.get("live").unwrap(), &Value::Bool(true));
            // round-robin over two live replicas splits evenly
            assert_eq!(r.get("answered").unwrap().as_usize().unwrap(), total as usize / 2);
        }

        drop(client);
        hr.join().unwrap().unwrap();
        drop(router);
        h1.join().unwrap().unwrap();
        h2.join().unwrap().unwrap();
    }

    #[test]
    fn router_rejects_mismatched_clients_by_name() {
        let d = 4;
        let model = test_model(d, 7, "sg2");
        let (a1, h1) = spawn_replica(model, replica_opts(), 1);
        let router =
            Arc::new(Router::connect(&[a1], test_router_opts(d)).expect("router connects"));
        let (addr, hr) = spawn_router(Arc::clone(&router), 1);

        let err = ServeClient::connect(&addr, 6, &fast_deadlines()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("d=6"), "must name the client's d: {msg}");
        assert!(msg.contains("d=4"), "must name the served d: {msg}");

        hr.join().unwrap().unwrap();
        drop(router);
        h1.join().unwrap().unwrap();
    }

    #[test]
    fn router_startup_cross_check_names_the_disagreeing_replica() {
        let d = 4;
        let (a1, h1) = spawn_replica(test_model(d, 1, "sg2"), replica_opts(), 1);
        let (a2, h2) = spawn_replica(test_model(d, 2, "ac2"), replica_opts(), 1);
        let err =
            Router::connect(&[a1.clone(), a2.clone()], test_router_opts(d)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("sg2") && msg.contains("ac2"), "must name both families: {msg}");
        assert!(msg.contains(&a2), "must name the disagreeing replica: {msg}");
        h1.join().unwrap().unwrap();
        h2.join().unwrap().unwrap();
    }

    #[test]
    fn router_starts_with_a_dead_replica_and_serves_from_the_live_one() {
        let d = 4;
        // a closed port: bind then drop the listener, so connects are
        // refused immediately
        let dead_addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = Router::connect(
            &[dead_addr.clone(), dead_addr.clone()],
            test_router_opts(d),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("none of the 2 replicas"));

        let model = test_model(d, 9, "sg2");
        let (a1, h1) = spawn_replica(Arc::clone(&model), replica_opts(), 1);
        let router = Arc::new(
            Router::connect(&[a1, dead_addr.clone()], test_router_opts(d))
                .expect("one live replica suffices"),
        );
        assert_eq!(router.live_replicas(), 1);
        let (addr, hr) = spawn_router(Arc::clone(&router), 1);

        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        for q in 0..4 {
            let xs = points(d, 2, 300 + q);
            let expect = model.eval(&xs);
            match client.query(&xs).unwrap() {
                QueryReply::Answer { values, .. } => {
                    for (got, want) in values.iter().zip(&expect) {
                        assert_eq!(got.to_bits(), want.to_bits());
                    }
                }
                other => panic!("expected an answer, got {other:?}"),
            }
        }
        let snap = router.snapshot();
        assert_eq!(snap.answered, 4);
        assert_eq!(snap.rejected, 0);
        let dead = snap.replicas.iter().find(|r| r.addr == dead_addr).unwrap();
        assert!(!dead.live, "the unreachable slot stays ejected");
        assert_eq!(dead.answered, 0);

        drop(client);
        hr.join().unwrap().unwrap();
        drop(router);
        h1.join().unwrap().unwrap();
    }

    #[test]
    fn router_chaos_die_after_queries_fails_over_to_survivors() {
        let d = 4;
        let model = test_model(d, 13, "sg2");
        let mut faulty = replica_opts();
        faulty.fault = FaultPlan::parse("die_after_queries=1").unwrap();
        let (a1, h1) = spawn_replica(Arc::clone(&model), faulty, 1);
        let (a2, h2) = spawn_replica(Arc::clone(&model), replica_opts(), 1);
        let (a3, h3) = spawn_replica(Arc::clone(&model), replica_opts(), 1);
        let router = Arc::new(
            Router::connect(&[a1, a2, a3], test_router_opts(d)).expect("router connects"),
        );
        let (addr, hr) = spawn_router(Arc::clone(&router), 1);

        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        let total = 12u64;
        for q in 0..total {
            let xs = points(d, 3, 500 + q);
            let expect = model.eval(&xs);
            match client.query(&xs).unwrap() {
                QueryReply::Answer { values, .. } => {
                    for (got, want) in values.iter().zip(&expect) {
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "failover answers must stay bitwise"
                        );
                    }
                }
                other => panic!("query {q}: expected an answer, got {other:?}"),
            }
        }

        let snap = router.snapshot();
        assert_eq!(snap.queries, total, "every query counted once");
        assert_eq!(snap.answered, total, "survivors absorb the dead replica's share");
        assert_eq!(snap.rejected, 0);
        assert_eq!(snap.queries, snap.answered + snap.rejected);
        assert!(snap.retried >= 1, "the failed query must have been retried: {snap:?}");
        assert!(snap.ejections >= 1, "the dead replica must be ejected: {snap:?}");
        assert_eq!(router.live_replicas(), 2);

        drop(client);
        hr.join().unwrap().unwrap();
        drop(router);
        h1.join().unwrap().unwrap();
        h2.join().unwrap().unwrap();
        h3.join().unwrap().unwrap();
    }

    #[test]
    fn router_chaos_corrupt_answer_frames_are_survived() {
        let d = 4;
        let model = test_model(d, 21, "sg2");
        let mut faulty = replica_opts();
        faulty.fault = FaultPlan::parse("corrupt_frame@QUERY").unwrap();
        let (a1, h1) = spawn_replica(Arc::clone(&model), faulty, 1);
        let (a2, h2) = spawn_replica(Arc::clone(&model), replica_opts(), 1);
        let router = Arc::new(
            Router::connect(&[a1, a2], test_router_opts(d)).expect("router connects"),
        );
        let (addr, hr) = spawn_router(Arc::clone(&router), 1);

        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        let total = 4u64;
        for q in 0..total {
            let xs = points(d, 2, 700 + q);
            let expect = model.eval(&xs);
            match client.query(&xs).unwrap() {
                QueryReply::Answer { values, .. } => {
                    for (got, want) in values.iter().zip(&expect) {
                        assert_eq!(got.to_bits(), want.to_bits());
                    }
                }
                other => panic!("expected an answer, got {other:?}"),
            }
        }
        let snap = router.snapshot();
        assert_eq!(snap.answered, total);
        assert_eq!(snap.queries, snap.answered + snap.rejected);
        assert!(snap.retried >= 1, "the corrupted round trip must retry: {snap:?}");
        assert!(snap.ejections >= 1, "the corrupting replica must be ejected: {snap:?}");

        drop(client);
        hr.join().unwrap().unwrap();
        drop(router);
        h1.join().unwrap().unwrap();
        h2.join().unwrap().unwrap();
    }

    #[test]
    fn router_relays_saturation_rejections_without_retrying() {
        let d = 4;
        let model = test_model(d, 33, "sg2");
        // both replicas advertise a tiny max_batch, so an oversize query
        // comes back ANSWER_REJECTED — the same status word saturation
        // uses, exercising the relay-don't-retry path deterministically
        let mut small = replica_opts();
        small.max_batch = 2;
        let (a1, h1) = spawn_replica(Arc::clone(&model), small.clone(), 1);
        let (a2, h2) = spawn_replica(Arc::clone(&model), small, 1);
        let router = Arc::new(
            Router::connect(&[a1, a2], test_router_opts(d)).expect("router connects"),
        );
        assert_eq!(router.max_batch(), 2, "the router advertises the pool minimum");
        let (addr, hr) = spawn_router(Arc::clone(&router), 1);

        let mut client = ServeClient::connect(&addr, d, &fast_deadlines()).unwrap();
        match client.query(&points(d, 4, 900)).unwrap() {
            QueryReply::Rejected(why) => {
                assert!(why.contains("max_batch"), "replica diagnostic relayed verbatim: {why}")
            }
            other => panic!("expected the relayed rejection, got {other:?}"),
        }
        // the pool is still healthy and still answers
        match client.query(&points(d, 2, 901)).unwrap() {
            QueryReply::Answer { .. } => {}
            other => panic!("expected an answer after the rejection, got {other:?}"),
        }

        let snap = router.snapshot();
        assert_eq!(snap.queries, 2);
        assert_eq!(snap.answered, 1);
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.saturated, 1, "the relayed rejection is tallied: {snap:?}");
        assert_eq!(snap.retried, 0, "rejections are backpressure, never retried");
        assert_eq!(snap.ejections, 0);
        assert_eq!(router.live_replicas(), 2);

        drop(client);
        hr.join().unwrap().unwrap();
        drop(router);
        h1.join().unwrap().unwrap();
        h2.join().unwrap().unwrap();
    }

    #[test]
    fn router_chaos_ejected_replica_rejoins_after_its_interval() {
        let d = 4;
        let model = test_model(d, 55, "sg2");
        // dies on its 2nd query; serves 2 sessions so the router's
        // rejoin handshake is accepted (and then dies again — the
        // fault state is process-wide and dead stays dead)
        let mut faulty = replica_opts();
        faulty.fault = FaultPlan::parse("die_after_queries=1").unwrap();
        let (a1, h1) = spawn_replica(Arc::clone(&model), faulty, 2);
        let mut opts = test_router_opts(d);
        opts.rejoin_interval = Duration::from_millis(1);
        let router =
            Arc::new(Router::connect(&[a1], opts).expect("router connects"));

        // query 1: served.  query 2: the replica dies -> ejected, and
        // with no survivor the router mints a local rejection.
        let xs = points(d, 2, 1000);
        let ok = router.forward(&encode_query(0, &xs, d));
        assert_eq!(answer_status(&ok), 0);
        let rejected = router.forward(&encode_query(1, &xs, d));
        assert_eq!(answer_status(&rejected), ANSWER_REJECTED);
        assert_eq!(router.live_replicas(), 0);

        // wait out the failure backoff (attempt 1 ~= 200ms + jitter),
        // then the re-dial handshakes -> a rejoin, even though the
        // still-dead fault plan fails the query right after
        std::thread::sleep(Duration::from_millis(400));
        let after = router.forward(&encode_query(2, &xs, d));
        assert_eq!(answer_status(&after), ANSWER_REJECTED);
        let snap = router.snapshot();
        assert!(snap.rejoins >= 1, "the restarted replica must rejoin: {snap:?}");
        assert!(snap.ejections >= 2, "and be re-ejected when it fails again: {snap:?}");
        assert_eq!(snap.queries, snap.answered + snap.rejected);

        drop(router);
        h1.join().unwrap().unwrap();
    }

    /// Encode a QUERY payload the way [`ServeClient::send_query`] does
    /// (tests that drive [`Router::forward`] directly).
    fn encode_query(id: u64, xs: &[f32], d: usize) -> Vec<u8> {
        let mut e = Enc::default();
        e.u64(id);
        e.u64((xs.len() / d) as u64);
        e.f32s(xs);
        e.buf
    }

    /// Decode just the status word of an ANSWER payload.
    fn answer_status(payload: &[u8]) -> u32 {
        let mut dec = Dec::new(payload);
        let _id = dec.u64().unwrap();
        dec.u32().unwrap()
    }
}
