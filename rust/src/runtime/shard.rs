//! Shard-plan execution layer: ONE scheduler from in-process threads to
//! multi-process workers, bitwise-deterministic.
//!
//! The native residual pipeline decomposes a batch into fixed-size point
//! chunks and reduces per-chunk losses/gradients in chunk order
//! (DESIGN.md §7).  This module makes that decomposition an explicit,
//! executor-independent artifact:
//!
//! * [`ShardPlan`] — the deterministic chunk assignment, computed once
//!   from the batch size and [`crate::nn::CHUNK_POINTS`].  It is a pure
//!   function of the *problem shape*, never of how many executors exist,
//!   so every f32 summation order — and therefore every trained bit —
//!   is identical for 1 thread, 16 threads, or 4 remote worker
//!   processes.
//! * [`ShardBackend`] — the one scheduling abstraction.  A backend runs
//!   the shards of a plan and reports a [`ShardResult`] (loss partial +
//!   gradient slice) *tagged by shard index*; the caller (the
//!   `NativeEngine` facade in `nn::native_loss`) merges results in
//!   shard-index order, so the reduction is the same no matter which
//!   executor produced which shard.
//! * [`InProcessBackend`] — the scoped-thread pool that used to live
//!   inline in `NativeEngine`, rehosted behind the trait with its
//!   per-worker workspace-pooled tapes intact.
//!
//! The TCP cluster backend (`runtime::cluster`) implements the same
//! trait over worker processes; rank 0 still merges in shard-index
//! order, which extends the thread-count-determinism guarantee across
//! the process boundary (same-ISA caveat: DESIGN.md §9/§10).

use anyhow::{bail, Result};

use crate::autodiff::Tape;
use crate::nn::{shard_loss_grad, Mlp, NativeBatch, ResidualOp, CHUNK_POINTS};
use crate::pde::PdeProblem;

/// One unit of residual work: a contiguous run of batch points.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shard {
    /// Position in the plan — the merge key.  Results are reduced in
    /// increasing `index`, whoever computed them.
    pub index: usize,
    /// First batch point of the shard.
    pub start: usize,
    /// Points in the shard (`CHUNK_POINTS`, except a shorter tail).
    pub nc: usize,
}

/// The deterministic chunk decomposition of one batch: a pure function
/// of `(n, chunk_points)`.  Executor counts never enter — that is the
/// whole determinism argument, so keep it that way.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Batch points covered by the plan.
    pub n: usize,
    /// Points per shard the plan was built with.
    pub chunk_points: usize,
    shards: Vec<Shard>,
}

impl ShardPlan {
    /// The plan every engine step uses: fixed [`CHUNK_POINTS`]-sized
    /// shards over the batch.
    pub fn for_batch(n: usize) -> Self {
        Self::with_chunk(n, CHUNK_POINTS)
    }

    /// Plan with an explicit chunk size (tests; the engine always uses
    /// [`ShardPlan::for_batch`]).
    pub fn with_chunk(n: usize, chunk_points: usize) -> Self {
        assert!(chunk_points > 0, "chunk_points must be positive");
        let n_tasks = n.div_ceil(chunk_points);
        let shards = (0..n_tasks)
            .map(|t| {
                let start = t * chunk_points;
                Shard { index: t, start, nc: chunk_points.min(n - start) }
            })
            .collect();
        Self { n, chunk_points, shards }
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Contiguous shard ranges for `workers` executors: worker `w` owns
    /// `assignment(workers)[w]`.  Deterministic given the worker count;
    /// results are merged by shard index, so the *assignment* only
    /// affects who computes what, never the reduced bits.
    pub fn assignment(&self, workers: usize) -> Vec<std::ops::Range<usize>> {
        split_range(&(0..self.len()), workers)
    }

    /// Sub-plan holding shards `range` of this plan, *indices
    /// preserved* — a worker runs a slice and its results still merge
    /// by global shard index.
    pub fn slice(&self, range: std::ops::Range<usize>) -> ShardPlan {
        ShardPlan {
            n: self.n,
            chunk_points: self.chunk_points,
            shards: self.shards[range].to_vec(),
        }
    }
}

/// Contiguous, disjoint, complete split of `range` across `workers`
/// executors — the same arithmetic [`ShardPlan::assignment`] uses over
/// the full plan, so reassigning a dead worker's range over the
/// survivors re-derives exactly the shards the first assignment would
/// have given a smaller cluster.  Never feeds the merge order.
pub(crate) fn split_range(
    range: &std::ops::Range<usize>,
    workers: usize,
) -> Vec<std::ops::Range<usize>> {
    let w = workers.max(1);
    let len = range.len();
    let per = len.div_ceil(w);
    (0..w)
        .map(|i| {
            (range.start + (i * per).min(len))..(range.start + ((i + 1) * per).min(len))
        })
        .collect()
}

/// Everything a backend needs to run one step's shards.  In-process
/// backends consume the live references; remote backends additionally
/// need the job spec they were connected with (`runtime::cluster`) to
/// have told their workers how to rebuild `problem`/`op`.
pub struct ShardJob<'a> {
    pub mlp: &'a Mlp,
    pub problem: &'a dyn PdeProblem,
    pub op: &'a dyn ResidualOp,
    pub batch: &'a NativeBatch<'a>,
}

/// Loss partial + gradient slice of one shard, tagged by shard index.
#[derive(Clone, Debug, Default)]
pub struct ShardResult {
    pub index: usize,
    /// Unnormalized chunk loss (f64, summed in index order upstream).
    pub loss: f64,
    /// Parameter-gradient contribution (packed order, unnormalized).
    pub grad: Vec<f32>,
}

/// A shard executor.  Implementations must fill `out[i]` with the result
/// of `plan.shards()[i]` (same order — `out[i].index ==
/// plan.shards()[i].index`); the caller performs the shard-index-ordered
/// reduction.  `out` is caller-owned so backends can recycle the
/// per-shard gradient buffers across steps.
pub trait ShardBackend {
    /// Run every shard of `plan` for `job`, filling `out` (resized to
    /// `plan.len()`).
    fn run_shards(
        &mut self,
        plan: &ShardPlan,
        job: &ShardJob,
        out: &mut Vec<ShardResult>,
    ) -> Result<()>;

    /// Concurrent executors (threads or worker processes) — informational
    /// only; never feeds the plan.
    fn parallelism(&self) -> usize;

    /// Human-readable executor description for run banners.
    fn label(&self) -> String;

    /// Drain recovery events (worker deaths, shard reassignments,
    /// rejoins, respawns) recorded since the last call, for the run
    /// log.  Purely-local backends have none.
    fn take_events(&mut self) -> Vec<String> {
        Vec::new()
    }

    /// Total plan-cache evictions across the backend's executors
    /// (surfaced in the run banner; see `HTE_PLAN_CACHE_CAP`).  Remote
    /// backends that cannot observe their workers' caches report 0.
    fn plan_evictions(&self) -> u64 {
        0
    }
}

/// Resize `out` to `n` slots, keeping existing gradient buffers for
/// reuse.
pub(crate) fn prepare_results(out: &mut Vec<ShardResult>, n: usize) {
    out.resize_with(n, ShardResult::default);
}

/// The in-process executor: scoped worker threads over per-worker
/// workspace-pooled tapes — the scheduling that used to live inline in
/// `NativeEngine`, now one `ShardBackend` among others.
pub struct InProcessBackend {
    threads: usize,
    workers: Vec<Tape>,
}

impl InProcessBackend {
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1), workers: Vec::new() }
    }

    /// Backend sized to the machine (capped — the shards are small).
    pub fn with_default_threads() -> Self {
        Self::new(crate::nn::default_threads())
    }
}

fn run_one_shard(tape: &mut Tape, job: &ShardJob, shard: &Shard, slot: &mut ShardResult) {
    slot.index = shard.index;
    slot.loss =
        shard_loss_grad(tape, job.mlp, job.op, job.problem, job.batch, shard, &mut slot.grad);
}

impl ShardBackend for InProcessBackend {
    fn run_shards(
        &mut self,
        plan: &ShardPlan,
        job: &ShardJob,
        out: &mut Vec<ShardResult>,
    ) -> Result<()> {
        let shards = plan.shards();
        let n_tasks = shards.len();
        prepare_results(out, n_tasks);
        let threads = self.threads.min(n_tasks).max(1);
        if self.workers.len() < threads {
            self.workers.resize_with(threads, Tape::new);
        }
        if threads == 1 {
            // no thread handoff for tiny batches / single-thread runs;
            // identical bits either way (same shards, same order)
            let tape = &mut self.workers[0];
            for (slot, shard) in out.iter_mut().zip(shards) {
                run_one_shard(tape, job, shard, slot);
            }
        } else {
            let per = n_tasks.div_ceil(threads);
            std::thread::scope(|s| {
                for (tape, (ochunk, schunk)) in
                    self.workers.iter_mut().zip(out.chunks_mut(per).zip(shards.chunks(per)))
                {
                    s.spawn(move || {
                        for (slot, shard) in ochunk.iter_mut().zip(schunk) {
                            run_one_shard(tape, job, shard, slot);
                        }
                    });
                }
            });
        }
        Ok(())
    }

    fn parallelism(&self) -> usize {
        self.threads
    }

    fn label(&self) -> String {
        format!("threads={}", self.threads)
    }

    fn plan_evictions(&self) -> u64 {
        self.workers.iter().map(|t| t.plan_evictions()).sum()
    }
}

/// Shard-index-ordered reduction shared by every consumer of
/// [`ShardBackend`] output: sum losses (f64) and gradients (f32) in
/// increasing shard index, then normalize by the batch size.  This is
/// THE reduction — single-process and cluster runs call this same code
/// on the same per-shard bits, which is what makes them byte-identical.
pub fn merge_shard_results(
    results: &[ShardResult],
    n: usize,
    n_params: usize,
    grad: &mut Vec<f32>,
) -> Result<f32> {
    grad.clear();
    grad.resize(n_params, 0.0);
    let mut loss_sum = 0.0f64;
    for (t, r) in results.iter().enumerate() {
        if r.index != t {
            bail!("shard results out of order: slot {t} holds shard {}", r.index);
        }
        if r.grad.len() != n_params {
            bail!(
                "shard {t} returned {} gradient floats, expected {n_params}",
                r.grad.len()
            );
        }
        loss_sum += r.loss;
        for (o, &x) in grad.iter_mut().zip(&r.grad) {
            *o += x;
        }
    }
    let inv_n = 1.0 / n as f32;
    for o in grad.iter_mut() {
        *o *= inv_n;
    }
    Ok((loss_sum / n as f64) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{hte_residual_loss_and_grad, NativeEngine, TraceResidual};
    use crate::pde::{Domain, DomainSampler, SineGordon2Body};
    use crate::rng::{fill_rademacher, Normal, Xoshiro256pp};

    #[test]
    fn shard_plan_covers_batch_with_fixed_chunks() {
        for n in [1usize, 3, 4, 5, 9, 16, 17] {
            let plan = ShardPlan::for_batch(n);
            assert_eq!(plan.n, n);
            assert_eq!(plan.chunk_points, CHUNK_POINTS);
            assert_eq!(plan.len(), n.div_ceil(CHUNK_POINTS));
            let mut covered = 0;
            for (t, shard) in plan.shards().iter().enumerate() {
                assert_eq!(shard.index, t);
                assert_eq!(shard.start, t * CHUNK_POINTS);
                assert!(shard.nc >= 1 && shard.nc <= CHUNK_POINTS);
                covered += shard.nc;
            }
            assert_eq!(covered, n, "shards must partition the batch");
        }
    }

    /// The plan is a pure function of the batch shape: executor counts
    /// never enter, so two plans for the same batch are identical.
    #[test]
    fn shard_plan_is_independent_of_executors() {
        let a = ShardPlan::for_batch(11);
        let b = ShardPlan::for_batch(11);
        assert_eq!(a.shards(), b.shards());
        // the assignment distributes the *same* shards for any worker
        // count — disjoint, contiguous, complete
        for workers in 1..=5 {
            let ranges = a.assignment(workers);
            assert_eq!(ranges.len(), workers);
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next.min(a.len()));
                assert!(r.end >= r.start && r.end <= a.len());
                next = r.end.max(next);
            }
            assert_eq!(next, a.len(), "assignment must cover every shard");
        }
    }

    /// Reassignment arithmetic: any sub-range splits into contiguous,
    /// disjoint, complete parts for any survivor count — including more
    /// survivors than shards (trailing empty parts).
    #[test]
    fn shard_split_range_covers_any_subrange() {
        for (start, end) in [(0usize, 0usize), (0, 1), (0, 7), (2, 9), (5, 6)] {
            for workers in 1..=4 {
                let parts = split_range(&(start..end), workers);
                assert_eq!(parts.len(), workers);
                let mut next = start;
                for p in &parts {
                    assert_eq!(p.start, next.min(end));
                    assert!(p.end >= p.start && p.end <= end);
                    next = p.end.max(next);
                }
                assert_eq!(next, end, "{start}..{end} over {workers}: must cover the range");
            }
        }
        // the full-plan assignment is the same arithmetic
        let plan = ShardPlan::for_batch(11);
        assert_eq!(plan.assignment(3), split_range(&(0..plan.len()), 3));
    }

    #[test]
    fn shard_plan_slice_preserves_global_indices() {
        let plan = ShardPlan::for_batch(10); // 3 shards of 4,4,2
        let tail = plan.slice(1..3);
        assert_eq!(tail.len(), 2);
        assert_eq!(tail.shards()[0].index, 1);
        assert_eq!(tail.shards()[1].index, 2);
        assert_eq!(tail.shards()[1].nc, 2);
        assert_eq!(tail.n, plan.n, "slices keep the full-batch context");
    }

    fn sg_case(
        d: usize,
        n: usize,
        v: usize,
    ) -> (Mlp, SineGordon2Body, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Xoshiro256pp::new(41);
        let mlp = Mlp::init(d, &mut rng);
        let problem = SineGordon2Body::new(d);
        let mut sampler = DomainSampler::new(Domain::UnitBall, d, rng.fork(1));
        let xs = sampler.batch(n);
        let mut probes = vec![0.0f32; v * d];
        fill_rademacher(&mut rng, &mut probes);
        let mut coeff = vec![0.0f32; d - 1];
        Normal::new().fill_f32(&mut rng, &mut coeff);
        (mlp, problem, xs, probes, coeff)
    }

    /// The rehosted thread pool produces exactly the bits the engine
    /// facade reports, for any thread count, and a sliced plan produces
    /// the same per-shard results as the full plan.
    #[test]
    fn in_process_backend_shards_match_engine_bitwise() {
        let (mlp, problem, xs, probes, coeff) = sg_case(5, 11, 3);
        let batch = NativeBatch { xs: &xs, probes: &probes, coeff: &coeff, n: 11, v: 3 };
        let (loss_ref, grad_ref) = hte_residual_loss_and_grad(&mlp, &problem, &batch);

        let plan = ShardPlan::for_batch(11);
        let job = ShardJob { mlp: &mlp, problem: &problem, op: &TraceResidual, batch: &batch };
        for threads in [1usize, 2, 5] {
            let mut backend = InProcessBackend::new(threads);
            let mut results = Vec::new();
            backend.run_shards(&plan, &job, &mut results).unwrap();
            assert_eq!(results.len(), plan.len());
            let mut grad = Vec::new();
            let loss = merge_shard_results(&results, 11, mlp.n_params(), &mut grad).unwrap();
            assert_eq!(loss.to_bits(), loss_ref.to_bits(), "threads={threads}");
            for (a, b) in grad.iter().zip(&grad_ref) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }

            // a worker running only the tail slice reports the same
            // per-shard bits the full run produced
            let sub = plan.slice(1..plan.len());
            let mut sub_results = Vec::new();
            backend.run_shards(&sub, &job, &mut sub_results).unwrap();
            for (r_sub, r_full) in sub_results.iter().zip(&results[1..]) {
                assert_eq!(r_sub.index, r_full.index);
                assert_eq!(r_sub.loss.to_bits(), r_full.loss.to_bits());
                for (a, b) in r_sub.grad.iter().zip(&r_full.grad) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    #[test]
    fn merge_rejects_out_of_order_and_short_results() {
        let ok = ShardResult { index: 0, loss: 1.0, grad: vec![1.0, 2.0] };
        let mut grad = Vec::new();
        let loss = merge_shard_results(&[ok.clone()], 2, 2, &mut grad).unwrap();
        assert!((loss - 0.5).abs() < 1e-7);
        assert_eq!(grad, vec![0.5, 1.0]);
        let misordered = ShardResult { index: 1, ..ok.clone() };
        assert!(merge_shard_results(&[misordered], 2, 2, &mut grad).is_err());
        let short = ShardResult { grad: vec![1.0], ..ok };
        let err = merge_shard_results(&[short], 2, 2, &mut grad).unwrap_err().to_string();
        assert!(err.contains("expected 2"), "{err}");
    }

    /// `NativeEngine::with_backend` is the same engine: the facade over
    /// an explicit backend matches the default-constructed one bitwise.
    #[test]
    fn engine_facade_over_explicit_backend_shards_bitwise() {
        let (mlp, problem, xs, probes, coeff) = sg_case(4, 9, 2);
        let batch = NativeBatch { xs: &xs, probes: &probes, coeff: &coeff, n: 9, v: 2 };
        let mut default_engine = NativeEngine::new(3);
        let mut explicit = NativeEngine::with_backend(Box::new(InProcessBackend::new(3)));
        let (mut g1, mut g2) = (Vec::new(), Vec::new());
        let l1 = default_engine.loss_and_grad(&mlp, &problem, &batch, &mut g1).unwrap();
        let l2 = explicit.loss_and_grad(&mlp, &problem, &batch, &mut g2).unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits());
        for (a, b) in g1.iter().zip(&g2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(explicit.threads(), 3);
        assert!(explicit.backend_label().contains("threads=3"));
    }
}
