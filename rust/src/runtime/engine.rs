//! PJRT execution engine: loads HLO-text artifacts, compiles them once,
//! and runs them with device-resident buffers.
//!
//! Based on the /opt/xla-example/load_hlo pattern; every artifact has a
//! single non-tuple output so `execute_b` output buffers feed straight
//! back into the next step (DESIGN.md §6).
//!
//! `PjRtClient` is `Rc`-backed (not `Send`): one `Engine` per thread; the
//! sweep runner creates a fresh engine inside each worker thread.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

use super::manifest::{Entry, Manifest};

pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Load the manifest and create a CPU PJRT client.
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Compile (or fetch from cache) the executable for a manifest entry.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.get(name)?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&computation)
                .with_context(|| format!("compiling artifact {name}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Upload a row-major f32 host buffer.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Execute and return the single (non-tuple) output buffer.
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let mut out = exe.execute_b(args)?;
        let mut replica = out.pop().context("no output replica")?;
        let buffer = replica.pop().context("no output buffer")?;
        anyhow::ensure!(replica.is_empty(), "expected a single output buffer");
        Ok(buffer)
    }

    /// Copy a whole f32 buffer back to the host.
    /// (The CPU PJRT plugin does not implement CopyRawToHost, so this
    /// goes through a literal — see EXPERIMENTS.md §Perf for the cost.)
    pub fn download(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let literal = buf.to_literal_sync()?;
        Ok(literal.to_vec::<f32>()?)
    }

    /// Convenience: entry lookup by attributes (see `Manifest::find`).
    pub fn find_entry(
        &self,
        kind: &str,
        family: &str,
        method: &str,
        d: usize,
        v: Option<usize>,
    ) -> Result<Entry> {
        Ok(self.manifest.find(kind, family, method, d, v)?.clone())
    }
}
