//! Minimal dense tensor substrate (f32, row-major) for the native engine.
//!
//! Only what the native MLP / autodiff need: blocked matmul (plus `_into`
//! / `_acc` variants that write into caller-owned buffers), elementwise
//! ops, reductions, and a `BufferPool` workspace the tape allocates
//! through so a steady-state training step performs no heap allocation.
//! No views or strides — shapes are small and regular.

mod matmul;
mod pool;
pub mod simd;

pub use matmul::{
    fused_matmul_bias, fused_matmul_bias_tanh, matmul_acc, matmul_acc_scalar, matmul_into,
    matmul_nt_acc, matmul_nt_acc_scalar, matmul_nt_into, matmul_tn_acc, matmul_tn_acc_scalar,
    matmul_tn_into,
};
pub use pool::BufferPool;
pub use simd::{detect_simd_level, force_simd_level, simd_level, simd_level_guard, SimdLevel};

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let numel = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; numel] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// [m, k] @ [k, n] -> [m, n]
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "inner dims {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        matmul_into(&self.data, &other.data, &mut out.data, m, k, n);
        out
    }

    /// a^T @ b with a: [k, m], b: [k, n] -> [m, n] (for backprop).
    pub fn matmul_tn(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (k, m) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2);
        let mut out = Tensor::zeros(&[m, n]);
        matmul_tn_acc(&self.data, &other.data, &mut out.data, k, m, n);
        out
    }

    /// a @ b^T with a: [m, k], b: [n, k] -> [m, n] (for backprop).
    pub fn matmul_nt(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (n, k2) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2);
        let mut out = Tensor::zeros(&[m, n]);
        matmul_nt_acc(&self.data, &other.data, &mut out.data, m, k, n);
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&v| f(v)).collect() }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "elementwise shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, alpha: f32) -> Tensor {
        self.map(|v| alpha * v)
    }

    /// Add a [n] row vector to every row of a [m, n] matrix.
    pub fn add_row(&self, row: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(row.numel(), self.shape[1]);
        let n = self.shape[1];
        let mut out = self.clone();
        for r in out.data.chunks_mut(n) {
            for (v, &b) in r.iter_mut().zip(&row.data) {
                *v += b;
            }
        }
        out
    }

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// Sum of a [m, n] matrix over rows -> [n] (bias gradient).
    pub fn sum_rows(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[n]);
        for i in 0..m {
            for j in 0..n {
                out.data[j] += self.data[i * n + j];
            }
        }
        out
    }

    pub fn dot(&self, other: &Tensor) -> f32 {
        assert_eq!(self.numel(), other.numel());
        self.data.iter().zip(&other.data).map(|(&a, &b)| a * b).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_hand_values() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_transposed_variants_agree() {
        let a = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(&[3, 4], (0..12).map(|i| i as f32).collect());
        // a^T @ b == transpose(a) matmul b
        let at = Tensor::from_vec(&[2, 3], vec![1., 3., 5., 2., 4., 6.]);
        assert_eq!(a.matmul_tn(&b).data, at.matmul(&b).data);
        // a @ b2^T
        let b2 = Tensor::from_vec(&[4, 2], (0..8).map(|i| i as f32).collect());
        let b2t = Tensor::from_vec(&[2, 4], vec![0., 2., 4., 6., 1., 3., 5., 7.]);
        assert_eq!(a.matmul_nt(&b2).data, a.matmul(&b2t).data);
    }

    #[test]
    fn elementwise_and_reductions() {
        let a = Tensor::from_vec(&[2, 2], vec![1., -2., 3., -4.]);
        let b = Tensor::from_vec(&[2, 2], vec![1., 1., 2., 2.]);
        assert_eq!(a.add(&b).data, vec![2., -1., 5., -2.]);
        assert_eq!(a.mul(&b).data, vec![1., -2., 6., -8.]);
        assert_eq!(a.scale(2.0).data, vec![2., -4., 6., -8.]);
        assert_eq!(a.sum(), -2.0);
        assert_eq!(a.sum_rows().data, vec![4., -6.]);
        assert_eq!(a.dot(&b), -3.0);
        let row = Tensor::from_vec(&[2], vec![10., 20.]);
        assert_eq!(a.add_row(&row).data, vec![11., 18., 13., 16.]);
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn matmul_shape_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 2]);
        let _ = a.matmul(&b);
    }
}
