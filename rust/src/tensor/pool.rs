//! Reusable f32 buffer pool — the tape's workspace.
//!
//! The native training step builds and tears down the same graph every
//! iteration, so every intermediate has the same size step after step.
//! Routing allocations through this free-list means the first step pays
//! for the buffers and every later step reuses them: the hot loop is
//! allocation-free at steady state.
//!
//! Buffers handed out are always zeroed to `len`, so results never depend
//! on what a recycled buffer previously held — a precondition for the
//! bit-stable multi-threaded reduction in `nn::native_loss`.

/// LIFO free-list of `Vec<f32>` buffers.
#[derive(Default)]
pub struct BufferPool {
    free: Vec<Vec<f32>>,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of buffers currently parked in the pool.
    pub fn len(&self) -> usize {
        self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.free.is_empty()
    }

    /// Take a buffer of exactly `len` zeroed elements (recycled if possible).
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        match self.free.pop() {
            Some(mut buf) => {
                buf.clear();
                buf.resize(len, 0.0);
                buf
            }
            None => vec![0.0; len],
        }
    }

    /// Return a buffer to the pool for reuse.
    pub fn give(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.free.push(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_and_zeroes() {
        let mut pool = BufferPool::new();
        let mut a = pool.take_zeroed(8);
        assert_eq!(a, vec![0.0; 8]);
        a.iter_mut().for_each(|v| *v = 3.0);
        let cap = a.capacity();
        pool.give(a);
        assert_eq!(pool.len(), 1);
        // smaller request reuses the same allocation, fully zeroed
        let b = pool.take_zeroed(4);
        assert_eq!(b, vec![0.0; 4]);
        assert_eq!(b.capacity(), cap);
        assert!(pool.is_empty());
    }

    #[test]
    fn grows_when_needed() {
        let mut pool = BufferPool::new();
        pool.give(vec![1.0; 2]);
        let c = pool.take_zeroed(16);
        assert_eq!(c.len(), 16);
        assert!(c.iter().all(|&v| v == 0.0));
    }
}
