//! Reusable f32 buffer pool — the tape's workspace.
//!
//! The native training step builds and tears down the same graph every
//! iteration, so every intermediate has the same size step after step.
//! Routing allocations through this free-list means the first step pays
//! for the buffers and every later step reuses them: the hot loop is
//! allocation-free at steady state.
//!
//! Buffers are parked in **exact-length buckets**: a `take_zeroed(len)`
//! is a hit only when a buffer of precisely that length was given back,
//! so mixed-shape workloads (a primal `[n, c]` next to a tangent
//! `[n·v, c]`) reuse each shape's own buffer instead of repeatedly
//! resizing (and refilling) whatever was returned last.  [`BufferPool::
//! alloc_count`] counts the misses, which is what the steady-state
//! no-allocation tests assert on.
//!
//! Buffers handed out are always zeroed to `len`, so results never depend
//! on what a recycled buffer previously held — a precondition for the
//! bit-stable multi-threaded reduction in `nn::native_loss`.

use std::collections::HashMap;

/// Size-bucketed LIFO free-list of `Vec<f32>` buffers.
#[derive(Default)]
pub struct BufferPool {
    /// Exact length -> parked buffers of that length.
    buckets: HashMap<usize, Vec<Vec<f32>>>,
    /// Fresh heap allocations performed by [`BufferPool::take_zeroed`].
    allocs: usize,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of buffers currently parked in the pool.
    pub fn len(&self) -> usize {
        self.buckets.values().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fresh allocations made so far (bucket misses).  Steady-state hot
    /// loops should hold this constant.
    pub fn alloc_count(&self) -> usize {
        self.allocs
    }

    /// Take a buffer of exactly `len` zeroed elements (recycled if a
    /// same-length buffer is parked, freshly allocated otherwise).
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        match self.buckets.get_mut(&len).and_then(Vec::pop) {
            Some(mut buf) => {
                buf.fill(0.0);
                buf
            }
            None => {
                self.allocs += 1;
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer to the pool for reuse (bucketed by its length).
    pub fn give(&mut self, buf: Vec<f32>) {
        if !buf.is_empty() {
            self.buckets.entry(buf.len()).or_default().push(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_and_zeroes() {
        let mut pool = BufferPool::new();
        let mut a = pool.take_zeroed(8);
        assert_eq!(a, vec![0.0; 8]);
        a.iter_mut().for_each(|v| *v = 3.0);
        let cap = a.capacity();
        pool.give(a);
        assert_eq!(pool.len(), 1);
        // A same-length request reuses the same allocation, fully zeroed.
        let b = pool.take_zeroed(8);
        assert_eq!(b, vec![0.0; 8]);
        assert_eq!(b.capacity(), cap);
        assert!(pool.is_empty());
    }

    #[test]
    fn grows_when_needed() {
        let mut pool = BufferPool::new();
        pool.give(vec![1.0; 2]);
        // Different length: the parked buffer stays in its bucket and a
        // fresh one is allocated.
        let c = pool.take_zeroed(16);
        assert_eq!(c.len(), 16);
        assert!(c.iter().all(|&v| v == 0.0));
        assert_eq!(pool.len(), 1);
    }

    #[test]
    fn plan_arena_steady_state_two_sizes_do_not_allocate() {
        let mut pool = BufferPool::new();
        // Warm-up: first touch of each size allocates.
        let a = pool.take_zeroed(64);
        let b = pool.take_zeroed(640);
        pool.give(a);
        pool.give(b);
        let warm = pool.alloc_count();
        assert_eq!(warm, 2);
        // Steady state: interleaved give/take cycles at two sizes — the
        // mixed-shape pattern of a primal next to a tangent stream —
        // must be all bucket hits.
        for _ in 0..100 {
            let a = pool.take_zeroed(64);
            let b = pool.take_zeroed(640);
            assert_eq!(a.len(), 64);
            assert_eq!(b.len(), 640);
            pool.give(b);
            pool.give(a);
        }
        assert_eq!(pool.alloc_count(), warm, "steady-state cycles allocated");
    }
}
