//! Runtime-dispatched SIMD layer for the native hot path.
//!
//! Everything the jet-stream pipeline spends time on — the six matmul
//! variants and the tape's elementwise executors (broadcast-row
//! products, jet factor combinations, axpy-style adjoint accumulation)
//! — funnels through the kernels in this module.  A [`SimdLevel`] is
//! detected once at startup (`is_x86_feature_detected!("avx2")` on
//! x86_64; NEON is part of the aarch64 baseline), overridable with
//! `HTE_SIMD=scalar|avx2|neon` for testing, and every kernel picks its
//! body off that level.  The vector bodies exist only under the `simd`
//! cargo feature; the default build always resolves to the scalar
//! reference.
//!
//! **The lane-independence rule** (DESIGN.md §9).  Every kernel here is
//! **bitwise identical** to its scalar reference, because vector lanes
//! are only ever laid across *independent* accumulation chains — output
//! columns of a matmul row, elements of an elementwise map, columns of a
//! per-group row reduction — never across the terms of a single chain.
//! Within a lane the operation sequence is exactly the scalar sequence:
//! explicit mul-then-add (`_mm256_mul_ps` + `_mm256_add_ps`, never a
//! fused `fmadd`, whose single rounding would change the low bits), and
//! the same expression association as the scalar code.  That invariant
//! is what lets the engine's 1/2/16-thread bitwise determinism survive
//! vectorization, and it is enforced by the `to_bits` property tests
//! below and the `rows_simd` gate of `benches/perf_breakdown.rs`.
//!
//! Transcendentals stay scalar libm: `tanh`, `sin` and `cos` values are
//! byte-for-byte those of the scalar engine, so only polynomial factor
//! combinations are vectorized.
//!
//! Layout note: kernels take raw `[rows*c]` slices with an explicit
//! `group` so the primal-stream factors (shape `[n, c]`) can be
//! broadcast by row index `p = r / group` against `[n*group, c]`
//! derivative streams without materializing them — the same convention
//! as the fused tanh-jet tape ops they serve.

use std::sync::atomic::{AtomicU8, Ordering};

/// The instruction set the dispatched kernels run with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Reference implementation; always available.
    Scalar,
    /// 8-lane f32 via `std::arch::x86_64` (requires the `simd` feature
    /// and a runtime `avx2` detection hit).
    Avx2,
    /// 4-lane f32 via `std::arch::aarch64` (requires the `simd` feature;
    /// NEON is part of the aarch64 baseline, so no runtime probe).
    Neon,
}

impl SimdLevel {
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// Whether this level actually vectorizes (the perf gates exempt the
    /// scalar fallback).
    pub fn is_vector(self) -> bool {
        !matches!(self, SimdLevel::Scalar)
    }

    fn code(self) -> u8 {
        match self {
            SimdLevel::Scalar => 1,
            SimdLevel::Avx2 => 2,
            SimdLevel::Neon => 3,
        }
    }

    fn from_code(code: u8) -> Self {
        match code {
            2 => SimdLevel::Avx2,
            3 => SimdLevel::Neon,
            _ => SimdLevel::Scalar,
        }
    }
}

/// 0 = uninitialized; otherwise a `SimdLevel::code`.
static LEVEL: AtomicU8 = AtomicU8::new(0);

/// The best level this build + host supports, ignoring `HTE_SIMD`.
#[allow(unreachable_code)]
pub fn detect_simd_level() -> SimdLevel {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        return SimdLevel::Neon;
    }
    SimdLevel::Scalar
}

/// Resolve an `HTE_SIMD` override against what is actually available:
/// a level the build/host cannot run falls back to the detected one.
fn level_from_env(var: Option<&str>, detected: SimdLevel) -> SimdLevel {
    match var {
        Some("scalar") => SimdLevel::Scalar,
        Some("avx2") if detected == SimdLevel::Avx2 => SimdLevel::Avx2,
        Some("neon") if detected == SimdLevel::Neon => SimdLevel::Neon,
        Some(other) => {
            eprintln!(
                "HTE_SIMD={other:?} is not available in this build/host \
                 (detected: {}); using the detected level",
                detected.name()
            );
            detected
        }
        None => detected,
    }
}

/// The level every kernel dispatches on.  Detected once (honoring
/// `HTE_SIMD`) and cached; [`force_simd_level`] replaces the cache.
pub fn simd_level() -> SimdLevel {
    match LEVEL.load(Ordering::Relaxed) {
        0 => {
            let env = std::env::var("HTE_SIMD").ok();
            let level = level_from_env(env.as_deref(), detect_simd_level());
            LEVEL.store(level.code(), Ordering::Relaxed);
            level
        }
        code => SimdLevel::from_code(code),
    }
}

/// Install a dispatch level (the programmatic equivalent of `HTE_SIMD`,
/// for the property tests and the simd-vs-scalar bench rows).  Requests
/// the build/host cannot satisfy degrade to `Scalar`; the level actually
/// installed is returned.  Because every level produces bitwise
/// identical results, flipping this mid-run never changes any output —
/// but tests that *time or compare* levels should serialize through
/// [`simd_level_guard`].
pub fn force_simd_level(level: SimdLevel) -> SimdLevel {
    let applied = match level {
        SimdLevel::Scalar => SimdLevel::Scalar,
        requested => {
            if detect_simd_level() == requested {
                requested
            } else {
                SimdLevel::Scalar
            }
        }
    };
    LEVEL.store(applied.code(), Ordering::Relaxed);
    applied
}

/// Serializes tests/benches that flip the dispatch level with
/// [`force_simd_level`] (poisoning is ignored: the guarded state is a
/// single atomic).
pub fn simd_level_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// Lane abstraction: every kernel body is written once, generically
// ---------------------------------------------------------------------------

/// A register of `N` f32 lanes.  The `f32` impl (N = 1) *is* the scalar
/// reference; the vector impls must perform the identical operation
/// sequence per lane (plain mul/add/sub — no FMA contraction).
///
/// All methods are `unsafe` for uniformity with the `std::arch`
/// intrinsics they wrap; `ld`/`st` additionally require `p` valid for
/// `N` f32 reads/writes.
trait Lanes: Copy {
    const N: usize;
    unsafe fn ld(p: *const f32) -> Self;
    unsafe fn st(self, p: *mut f32);
    unsafe fn splat(v: f32) -> Self;
    unsafe fn mul(self, o: Self) -> Self;
    unsafe fn add(self, o: Self) -> Self;
    unsafe fn sub(self, o: Self) -> Self;
}

impl Lanes for f32 {
    const N: usize = 1;
    #[inline(always)]
    unsafe fn ld(p: *const f32) -> Self {
        *p
    }
    #[inline(always)]
    unsafe fn st(self, p: *mut f32) {
        *p = self;
    }
    #[inline(always)]
    unsafe fn splat(v: f32) -> Self {
        v
    }
    #[inline(always)]
    unsafe fn mul(self, o: Self) -> Self {
        self * o
    }
    #[inline(always)]
    unsafe fn add(self, o: Self) -> Self {
        self + o
    }
    #[inline(always)]
    unsafe fn sub(self, o: Self) -> Self {
        self - o
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod lanes_avx2 {
    use super::Lanes;
    use std::arch::x86_64::{
        __m256, _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
        _mm256_sub_ps,
    };

    /// 8 f32 lanes.  Deliberately no `_mm256_fmadd_ps` anywhere: fused
    /// contraction rounds once where the scalar reference rounds twice.
    #[derive(Clone, Copy)]
    pub struct V8(__m256);

    impl Lanes for V8 {
        const N: usize = 8;
        #[inline(always)]
        unsafe fn ld(p: *const f32) -> Self {
            V8(_mm256_loadu_ps(p))
        }
        #[inline(always)]
        unsafe fn st(self, p: *mut f32) {
            _mm256_storeu_ps(p, self.0)
        }
        #[inline(always)]
        unsafe fn splat(v: f32) -> Self {
            V8(_mm256_set1_ps(v))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            V8(_mm256_mul_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            V8(_mm256_add_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn sub(self, o: Self) -> Self {
            V8(_mm256_sub_ps(self.0, o.0))
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
use lanes_avx2::V8;

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod lanes_neon {
    use super::Lanes;
    use std::arch::aarch64::{
        float32x4_t, vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32, vsubq_f32,
    };

    /// 4 f32 lanes.  No `vfmaq_f32`: same no-contraction rule as AVX2.
    #[derive(Clone, Copy)]
    pub struct V4(float32x4_t);

    impl Lanes for V4 {
        const N: usize = 4;
        #[inline(always)]
        unsafe fn ld(p: *const f32) -> Self {
            V4(vld1q_f32(p))
        }
        #[inline(always)]
        unsafe fn st(self, p: *mut f32) {
            vst1q_f32(p, self.0)
        }
        #[inline(always)]
        unsafe fn splat(v: f32) -> Self {
            V4(vdupq_n_f32(v))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            V4(vmulq_f32(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            V4(vaddq_f32(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn sub(self, o: Self) -> Self {
            V4(vsubq_f32(self.0, o.0))
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
use lanes_neon::V4;

/// Stamp out the public dispatcher for a generic kernel body: AVX2 /
/// NEON when the detected level says so (the `simd` feature compiled the
/// bodies in), the f32 lane instantiation — the scalar reference —
/// otherwise.
macro_rules! dispatch_kernel {
    ($(#[$meta:meta])* $name:ident => $body:ident ( $($arg:ident : $ty:ty),* $(,)? )) => {
        $(#[$meta])*
        #[allow(clippy::too_many_arguments)]
        pub fn $name($($arg: $ty),*) {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            {
                if simd_level() == SimdLevel::Avx2 {
                    #[target_feature(enable = "avx2")]
                    #[allow(clippy::too_many_arguments)]
                    unsafe fn vector($($arg: $ty),*) {
                        $body::<V8>($($arg),*)
                    }
                    // SAFETY: the Avx2 level is only ever installed after
                    // `is_x86_feature_detected!("avx2")` succeeded.
                    unsafe { vector($($arg),*) };
                    return;
                }
            }
            #[cfg(all(feature = "simd", target_arch = "aarch64"))]
            {
                if simd_level() == SimdLevel::Neon {
                    // SAFETY: NEON is part of the aarch64 baseline.
                    unsafe { $body::<V4>($($arg),*) };
                    return;
                }
            }
            // SAFETY: the f32 lane impl is plain scalar arithmetic over
            // in-bounds indices (the bodies debug_assert the lengths).
            unsafe { $body::<f32>($($arg),*) }
        }
    };
}

// ---------------------------------------------------------------------------
// tanh factor expressions (shared by the vector main loops and the
// scalar remainder lanes — one source of truth per formula)
// ---------------------------------------------------------------------------

/// f1 = 1 − t².
#[inline(always)]
unsafe fn f1_of<L: Lanes>(t: L) -> L {
    L::splat(1.0).sub(t.mul(t))
}

/// f2 = −2·t·f1.
#[inline(always)]
unsafe fn f2_of<L: Lanes>(t: L, f1: L) -> L {
    L::splat(-2.0).mul(t).mul(f1)
}

/// f3 = f1·(6·t·t − 2).
#[inline(always)]
unsafe fn f3_of<L: Lanes>(t: L, f1: L) -> L {
    f1.mul(L::splat(6.0).mul(t).mul(t).sub(L::splat(2.0)))
}

/// f4 = f1·(16·t − 24·t·t·t).
#[inline(always)]
unsafe fn f4_of<L: Lanes>(t: L, f1: L) -> L {
    f1.mul(L::splat(16.0).mul(t).sub(L::splat(24.0).mul(t).mul(t).mul(t)))
}

/// f1' = −2·t.
#[inline(always)]
unsafe fn f1p_of<L: Lanes>(t: L) -> L {
    L::splat(-2.0).mul(t)
}

/// f2' = 6·t² − 2.
#[inline(always)]
unsafe fn f2p_of<L: Lanes>(t2: L) -> L {
    L::splat(6.0).mul(t2).sub(L::splat(2.0))
}

/// f3' = 16·t − 24·t²·t.
#[inline(always)]
unsafe fn f3p_of<L: Lanes>(t: L, t2: L) -> L {
    L::splat(16.0).mul(t).sub(L::splat(24.0).mul(t2).mul(t))
}

/// f4' = 120·t²·t² − 120·t² + 16.
#[inline(always)]
unsafe fn f4p_of<L: Lanes>(t2: L) -> L {
    L::splat(120.0)
        .mul(t2)
        .mul(t2)
        .sub(L::splat(120.0).mul(t2))
        .add(L::splat(16.0))
}

// ---------------------------------------------------------------------------
// Flat axpy-style kernels (adjoint accumulation)
// ---------------------------------------------------------------------------

#[inline(always)]
unsafe fn acc_add_body<L: Lanes>(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let n = out.len();
    let op = out.as_mut_ptr();
    let xp = x.as_ptr();
    let mut j = 0;
    while j + L::N <= n {
        L::ld(op.add(j)).add(L::ld(xp.add(j))).st(op.add(j));
        j += L::N;
    }
    while j < n {
        *op.add(j) += *xp.add(j);
        j += 1;
    }
}

dispatch_kernel! {
    /// out += x.
    acc_add => acc_add_body(out: &mut [f32], x: &[f32])
}

#[inline(always)]
unsafe fn acc_sub_body<L: Lanes>(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let n = out.len();
    let op = out.as_mut_ptr();
    let xp = x.as_ptr();
    let mut j = 0;
    while j + L::N <= n {
        L::ld(op.add(j)).sub(L::ld(xp.add(j))).st(op.add(j));
        j += L::N;
    }
    while j < n {
        *op.add(j) -= *xp.add(j);
        j += 1;
    }
}

dispatch_kernel! {
    /// out -= x.
    acc_sub => acc_sub_body(out: &mut [f32], x: &[f32])
}

#[inline(always)]
unsafe fn acc_scaled_body<L: Lanes>(out: &mut [f32], x: &[f32], alpha: f32) {
    debug_assert_eq!(out.len(), x.len());
    let n = out.len();
    let op = out.as_mut_ptr();
    let xp = x.as_ptr();
    let va = L::splat(alpha);
    let mut j = 0;
    while j + L::N <= n {
        L::ld(op.add(j)).add(va.mul(L::ld(xp.add(j)))).st(op.add(j));
        j += L::N;
    }
    while j < n {
        *op.add(j) += alpha * *xp.add(j);
        j += 1;
    }
}

dispatch_kernel! {
    /// out += alpha·x.
    acc_scaled => acc_scaled_body(out: &mut [f32], x: &[f32], alpha: f32)
}

#[inline(always)]
unsafe fn acc_mul_body<L: Lanes>(out: &mut [f32], g: &[f32], y: &[f32]) {
    debug_assert_eq!(out.len(), g.len());
    debug_assert_eq!(out.len(), y.len());
    let n = out.len();
    let op = out.as_mut_ptr();
    let gp = g.as_ptr();
    let yp = y.as_ptr();
    let mut j = 0;
    while j + L::N <= n {
        L::ld(op.add(j))
            .add(L::ld(gp.add(j)).mul(L::ld(yp.add(j))))
            .st(op.add(j));
        j += L::N;
    }
    while j < n {
        *op.add(j) += *gp.add(j) * *yp.add(j);
        j += 1;
    }
}

dispatch_kernel! {
    /// out += g ⊙ y (the product-rule adjoint).
    acc_mul => acc_mul_body(out: &mut [f32], g: &[f32], y: &[f32])
}

#[inline(always)]
unsafe fn acc_splat_body<L: Lanes>(out: &mut [f32], v: f32) {
    let n = out.len();
    let op = out.as_mut_ptr();
    let vv = L::splat(v);
    let mut j = 0;
    while j + L::N <= n {
        L::ld(op.add(j)).add(vv).st(op.add(j));
        j += L::N;
    }
    while j < n {
        *op.add(j) += v;
        j += 1;
    }
}

dispatch_kernel! {
    /// out += v (broadcast constant; the mean/sum adjoints).
    acc_splat => acc_splat_body(out: &mut [f32], v: f32)
}

#[inline(always)]
unsafe fn add_rows_body<L: Lanes>(out: &mut [f32], a: &[f32], bias: &[f32], c: usize) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(bias.len(), c);
    let rows = if c == 0 { 0 } else { out.len() / c };
    let bp = bias.as_ptr();
    for r in 0..rows {
        let op = out.as_mut_ptr().add(r * c);
        let ap = a.as_ptr().add(r * c);
        let mut j = 0;
        while j + L::N <= c {
            L::ld(ap.add(j)).add(L::ld(bp.add(j))).st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) = *ap.add(j) + *bp.add(j);
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// out[r, ·] = a[r, ·] + bias (row-broadcast bias add, forward).
    add_rows => add_rows_body(out: &mut [f32], a: &[f32], bias: &[f32], c: usize)
}

#[inline(always)]
unsafe fn add_rows_inplace_body<L: Lanes>(out: &mut [f32], bias: &[f32], c: usize) {
    debug_assert_eq!(bias.len(), c);
    let rows = if c == 0 { 0 } else { out.len() / c };
    let bp = bias.as_ptr();
    for r in 0..rows {
        let op = out.as_mut_ptr().add(r * c);
        let mut j = 0;
        while j + L::N <= c {
            L::ld(op.add(j)).add(L::ld(bp.add(j))).st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) += *bp.add(j);
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// out[r, ·] += bias — the in-place half of [`add_rows`], used by the
    /// fused plan instructions (DESIGN.md §12) where the unfused `a`
    /// operand has been eliminated.  Same per-element expression
    /// (`a[r,j] + bias[j]`) with `a` aliased to `out`, so the result bits
    /// match the two-buffer kernel exactly.
    add_rows_inplace => add_rows_inplace_body(out: &mut [f32], bias: &[f32], c: usize)
}

#[inline(always)]
unsafe fn broadcast_rows_bwd_body<L: Lanes>(ga: &mut [f32], g: &[f32], group: usize, c: usize) {
    debug_assert_eq!(g.len(), ga.len() * group);
    let rows = if c == 0 { 0 } else { g.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = ga.as_mut_ptr().add(p * c);
        let gp = g.as_ptr().add(r * c);
        let mut j = 0;
        while j + L::N <= c {
            L::ld(op.add(j)).add(L::ld(gp.add(j))).st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) += *gp.add(j);
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// ga[p, ·] += Σ over the group's g rows, in ascending row order
    /// (the `broadcast_rows` adjoint — each column is an independent
    /// chain, the r-order of the per-column sums is preserved).
    broadcast_rows_bwd => broadcast_rows_bwd_body(ga: &mut [f32], g: &[f32], group: usize, c: usize)
}

// ---------------------------------------------------------------------------
// Fused tanh-jet forward kernels (factor combinations, t0 broadcast by
// row index p = r / group)
// ---------------------------------------------------------------------------

#[inline(always)]
unsafe fn o1_expr<L: Lanes>(t: L, z1: L) -> L {
    f1_of(t).mul(z1)
}

#[inline(always)]
unsafe fn jet_o1_fwd_body<L: Lanes>(o: &mut [f32], t0: &[f32], z1: &[f32], group: usize, c: usize) {
    debug_assert_eq!(o.len(), z1.len());
    debug_assert_eq!(o.len(), t0.len() * group);
    let rows = if c == 0 { 0 } else { o.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = o.as_mut_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let z1p = z1.as_ptr().add(r * c);
        let mut j = 0;
        while j + L::N <= c {
            o1_expr::<L>(L::ld(tp.add(j)), L::ld(z1p.add(j))).st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) = o1_expr::<f32>(*tp.add(j), *z1p.add(j));
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// o1 = f1 ⊙ z1 (order-1 tanh-jet stream).
    jet_o1_fwd => jet_o1_fwd_body(o: &mut [f32], t0: &[f32], z1: &[f32], group: usize, c: usize)
}

#[inline(always)]
unsafe fn o2_expr<L: Lanes>(t: L, z1: L, z2: L) -> L {
    let f1 = f1_of(t);
    let f2 = f2_of(t, f1);
    f2.mul(z1).mul(z1).add(f1.mul(z2))
}

#[inline(always)]
unsafe fn jet_o2_fwd_body<L: Lanes>(
    o: &mut [f32],
    t0: &[f32],
    z1: &[f32],
    z2: &[f32],
    group: usize,
    c: usize,
) {
    debug_assert_eq!(o.len(), z1.len());
    debug_assert_eq!(o.len(), z2.len());
    debug_assert_eq!(o.len(), t0.len() * group);
    let rows = if c == 0 { 0 } else { o.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = o.as_mut_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let z1p = z1.as_ptr().add(r * c);
        let z2p = z2.as_ptr().add(r * c);
        let mut j = 0;
        while j + L::N <= c {
            o2_expr::<L>(L::ld(tp.add(j)), L::ld(z1p.add(j)), L::ld(z2p.add(j))).st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) = o2_expr::<f32>(*tp.add(j), *z1p.add(j), *z2p.add(j));
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// o2 = f2 ⊙ z1² + f1 ⊙ z2.
    jet_o2_fwd => jet_o2_fwd_body(o: &mut [f32], t0: &[f32], z1: &[f32], z2: &[f32], group: usize, c: usize)
}

#[inline(always)]
unsafe fn o3_expr<L: Lanes>(t: L, z1: L, z2: L, z3: L) -> L {
    let f1 = f1_of(t);
    let f2 = f2_of(t, f1);
    let f3 = f3_of(t, f1);
    f3.mul(z1)
        .mul(z1)
        .mul(z1)
        .add(L::splat(3.0).mul(f2).mul(z1).mul(z2))
        .add(f1.mul(z3))
}

#[inline(always)]
unsafe fn jet_o3_fwd_body<L: Lanes>(
    o: &mut [f32],
    t0: &[f32],
    z1: &[f32],
    z2: &[f32],
    z3: &[f32],
    group: usize,
    c: usize,
) {
    debug_assert_eq!(o.len(), z1.len());
    debug_assert_eq!(o.len(), z2.len());
    debug_assert_eq!(o.len(), z3.len());
    debug_assert_eq!(o.len(), t0.len() * group);
    let rows = if c == 0 { 0 } else { o.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = o.as_mut_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let z1p = z1.as_ptr().add(r * c);
        let z2p = z2.as_ptr().add(r * c);
        let z3p = z3.as_ptr().add(r * c);
        let mut j = 0;
        while j + L::N <= c {
            o3_expr::<L>(
                L::ld(tp.add(j)),
                L::ld(z1p.add(j)),
                L::ld(z2p.add(j)),
                L::ld(z3p.add(j)),
            )
            .st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) = o3_expr::<f32>(*tp.add(j), *z1p.add(j), *z2p.add(j), *z3p.add(j));
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// o3 = f3 ⊙ z1³ + 3 f2 ⊙ z1 z2 + f1 ⊙ z3.
    jet_o3_fwd => jet_o3_fwd_body(o: &mut [f32], t0: &[f32], z1: &[f32], z2: &[f32], z3: &[f32], group: usize, c: usize)
}

#[inline(always)]
unsafe fn o4_expr<L: Lanes>(t: L, z1: L, z2: L, z3: L, z4: L) -> L {
    let f1 = f1_of(t);
    let f2 = f2_of(t, f1);
    let f3 = f3_of(t, f1);
    let f4 = f4_of(t, f1);
    f4.mul(z1)
        .mul(z1)
        .mul(z1)
        .mul(z1)
        .add(L::splat(6.0).mul(f3).mul(z1).mul(z1).mul(z2))
        .add(L::splat(3.0).mul(f2).mul(z2).mul(z2))
        .add(L::splat(4.0).mul(f2).mul(z1).mul(z3))
        .add(f1.mul(z4))
}

#[inline(always)]
#[allow(clippy::too_many_arguments)]
unsafe fn jet_o4_fwd_body<L: Lanes>(
    o: &mut [f32],
    t0: &[f32],
    z1: &[f32],
    z2: &[f32],
    z3: &[f32],
    z4: &[f32],
    group: usize,
    c: usize,
) {
    debug_assert_eq!(o.len(), z1.len());
    debug_assert_eq!(o.len(), z2.len());
    debug_assert_eq!(o.len(), z3.len());
    debug_assert_eq!(o.len(), z4.len());
    debug_assert_eq!(o.len(), t0.len() * group);
    let rows = if c == 0 { 0 } else { o.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = o.as_mut_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let z1p = z1.as_ptr().add(r * c);
        let z2p = z2.as_ptr().add(r * c);
        let z3p = z3.as_ptr().add(r * c);
        let z4p = z4.as_ptr().add(r * c);
        let mut j = 0;
        while j + L::N <= c {
            o4_expr::<L>(
                L::ld(tp.add(j)),
                L::ld(z1p.add(j)),
                L::ld(z2p.add(j)),
                L::ld(z3p.add(j)),
                L::ld(z4p.add(j)),
            )
            .st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) =
                o4_expr::<f32>(*tp.add(j), *z1p.add(j), *z2p.add(j), *z3p.add(j), *z4p.add(j));
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// o4 = f4 ⊙ z1⁴ + 6 f3 ⊙ z1² z2 + 3 f2 ⊙ z2² + 4 f2 ⊙ z1 z3 + f1 ⊙ z4.
    jet_o4_fwd => jet_o4_fwd_body(o: &mut [f32], t0: &[f32], z1: &[f32], z2: &[f32], z3: &[f32], z4: &[f32], group: usize, c: usize)
}

// ---------------------------------------------------------------------------
// Fused tanh-jet backward kernels
// ---------------------------------------------------------------------------

#[inline(always)]
unsafe fn f1_acc_expr<L: Lanes>(g: L, t: L) -> L {
    g.mul(f1_of(t))
}

#[inline(always)]
unsafe fn jet_f1_acc_body<L: Lanes>(
    gz: &mut [f32],
    g: &[f32],
    t0: &[f32],
    group: usize,
    c: usize,
) {
    debug_assert_eq!(gz.len(), g.len());
    debug_assert_eq!(gz.len(), t0.len() * group);
    let rows = if c == 0 { 0 } else { gz.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = gz.as_mut_ptr().add(r * c);
        let gp = g.as_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let mut j = 0;
        while j + L::N <= c {
            L::ld(op.add(j))
                .add(f1_acc_expr::<L>(L::ld(gp.add(j)), L::ld(tp.add(j))))
                .st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) += f1_acc_expr::<f32>(*gp.add(j), *tp.add(j));
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// gz += g ⊙ bc(f1) — the z_k adjoint of the highest stream, and the
    /// plain tanh adjoint (group = 1, t0 = saved tanh values).
    jet_f1_acc => jet_f1_acc_body(gz: &mut [f32], g: &[f32], t0: &[f32], group: usize, c: usize)
}

#[inline(always)]
unsafe fn f2z1_expr<L: Lanes>(g: L, z1: L, t: L, coeff: L) -> L {
    let f1 = f1_of(t);
    let f2 = f2_of(t, f1);
    g.mul(coeff).mul(f2).mul(z1)
}

#[inline(always)]
unsafe fn jet_f2z1_acc_body<L: Lanes>(
    gz: &mut [f32],
    g: &[f32],
    z1: &[f32],
    t0: &[f32],
    coeff: f32,
    group: usize,
    c: usize,
) {
    debug_assert_eq!(gz.len(), g.len());
    debug_assert_eq!(gz.len(), z1.len());
    debug_assert_eq!(gz.len(), t0.len() * group);
    let rows = if c == 0 { 0 } else { gz.len() / c };
    let vc = L::splat(coeff);
    for r in 0..rows {
        let p = r / group;
        let op = gz.as_mut_ptr().add(r * c);
        let gp = g.as_ptr().add(r * c);
        let z1p = z1.as_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let mut j = 0;
        while j + L::N <= c {
            L::ld(op.add(j))
                .add(f2z1_expr::<L>(L::ld(gp.add(j)), L::ld(z1p.add(j)), L::ld(tp.add(j)), vc))
                .st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) += f2z1_expr::<f32>(*gp.add(j), *z1p.add(j), *tp.add(j), coeff);
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// gz += g·coeff·f2·z1 — the shared shape of the O2 z1 (coeff 2),
    /// O3 z2 (coeff 3) and O4 z3 (coeff 4) adjoints.
    jet_f2z1_acc => jet_f2z1_acc_body(gz: &mut [f32], g: &[f32], z1: &[f32], t0: &[f32], coeff: f32, group: usize, c: usize)
}

#[inline(always)]
unsafe fn o1_t0_expr<L: Lanes>(g: L, z1: L, t: L) -> L {
    g.mul(z1).mul(L::splat(-2.0).mul(t))
}

#[inline(always)]
unsafe fn jet_o1_bwd_t0_body<L: Lanes>(
    gt0: &mut [f32],
    g: &[f32],
    z1: &[f32],
    t0: &[f32],
    group: usize,
    c: usize,
) {
    debug_assert_eq!(g.len(), z1.len());
    debug_assert_eq!(g.len(), gt0.len() * group);
    debug_assert_eq!(gt0.len(), t0.len());
    let rows = if c == 0 { 0 } else { g.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = gt0.as_mut_ptr().add(p * c);
        let gp = g.as_ptr().add(r * c);
        let z1p = z1.as_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let mut j = 0;
        while j + L::N <= c {
            L::ld(op.add(j))
                .add(o1_t0_expr::<L>(L::ld(gp.add(j)), L::ld(z1p.add(j)), L::ld(tp.add(j))))
                .st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) += o1_t0_expr::<f32>(*gp.add(j), *z1p.add(j), *tp.add(j));
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// gt0[p] += g·z1·(−2t) group-summed in ascending row order
    /// (columns are independent chains; the r-order per column matches
    /// the scalar reference).
    jet_o1_bwd_t0 => jet_o1_bwd_t0_body(gt0: &mut [f32], g: &[f32], z1: &[f32], t0: &[f32], group: usize, c: usize)
}

#[inline(always)]
unsafe fn o2_t0_expr<L: Lanes>(g: L, z1: L, z2: L, t: L) -> L {
    let a = L::splat(6.0)
        .mul(t)
        .mul(t)
        .sub(L::splat(2.0))
        .mul(z1)
        .mul(z1);
    let b = L::splat(2.0).mul(t).mul(z2);
    g.mul(a.sub(b))
}

#[inline(always)]
unsafe fn jet_o2_bwd_t0_body<L: Lanes>(
    gt0: &mut [f32],
    g: &[f32],
    z1: &[f32],
    z2: &[f32],
    t0: &[f32],
    group: usize,
    c: usize,
) {
    debug_assert_eq!(g.len(), z1.len());
    debug_assert_eq!(g.len(), z2.len());
    debug_assert_eq!(g.len(), gt0.len() * group);
    let rows = if c == 0 { 0 } else { g.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = gt0.as_mut_ptr().add(p * c);
        let gp = g.as_ptr().add(r * c);
        let z1p = z1.as_ptr().add(r * c);
        let z2p = z2.as_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let mut j = 0;
        while j + L::N <= c {
            L::ld(op.add(j))
                .add(o2_t0_expr::<L>(
                    L::ld(gp.add(j)),
                    L::ld(z1p.add(j)),
                    L::ld(z2p.add(j)),
                    L::ld(tp.add(j)),
                ))
                .st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) += o2_t0_expr::<f32>(*gp.add(j), *z1p.add(j), *z2p.add(j), *tp.add(j));
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// gt0[p] += g·((6t²−2)·z1² − 2t·z2), group-summed in row order.
    jet_o2_bwd_t0 => jet_o2_bwd_t0_body(gt0: &mut [f32], g: &[f32], z1: &[f32], z2: &[f32], t0: &[f32], group: usize, c: usize)
}

#[inline(always)]
unsafe fn o3_z1_expr<L: Lanes>(g: L, z1: L, z2: L, t: L) -> L {
    let f1 = f1_of(t);
    let f2 = f2_of(t, f1);
    let f3 = f3_of(t, f1);
    g.mul(
        L::splat(3.0)
            .mul(f3)
            .mul(z1)
            .mul(z1)
            .add(L::splat(3.0).mul(f2).mul(z2)),
    )
}

#[inline(always)]
unsafe fn jet_o3_bwd_z1_body<L: Lanes>(
    gz1: &mut [f32],
    g: &[f32],
    z1: &[f32],
    z2: &[f32],
    t0: &[f32],
    group: usize,
    c: usize,
) {
    debug_assert_eq!(gz1.len(), g.len());
    debug_assert_eq!(gz1.len(), z1.len());
    debug_assert_eq!(gz1.len(), z2.len());
    debug_assert_eq!(gz1.len(), t0.len() * group);
    let rows = if c == 0 { 0 } else { gz1.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = gz1.as_mut_ptr().add(r * c);
        let gp = g.as_ptr().add(r * c);
        let z1p = z1.as_ptr().add(r * c);
        let z2p = z2.as_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let mut j = 0;
        while j + L::N <= c {
            L::ld(op.add(j))
                .add(o3_z1_expr::<L>(
                    L::ld(gp.add(j)),
                    L::ld(z1p.add(j)),
                    L::ld(z2p.add(j)),
                    L::ld(tp.add(j)),
                ))
                .st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) += o3_z1_expr::<f32>(*gp.add(j), *z1p.add(j), *z2p.add(j), *tp.add(j));
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// gz1 += g·(3 f3 z1² + 3 f2 z2) (order-3 z1 adjoint).
    jet_o3_bwd_z1 => jet_o3_bwd_z1_body(gz1: &mut [f32], g: &[f32], z1: &[f32], z2: &[f32], t0: &[f32], group: usize, c: usize)
}

#[inline(always)]
unsafe fn o3_t0_expr<L: Lanes>(g: L, z1: L, z2: L, z3: L, t: L) -> L {
    let t2 = t.mul(t);
    let f1p = f1p_of(t);
    let f2p = f2p_of(t2);
    let f3p = f3p_of(t, t2);
    g.mul(
        f3p.mul(z1)
            .mul(z1)
            .mul(z1)
            .add(L::splat(3.0).mul(f2p).mul(z1).mul(z2))
            .add(f1p.mul(z3)),
    )
}

#[inline(always)]
#[allow(clippy::too_many_arguments)]
unsafe fn jet_o3_bwd_t0_body<L: Lanes>(
    gt0: &mut [f32],
    g: &[f32],
    z1: &[f32],
    z2: &[f32],
    z3: &[f32],
    t0: &[f32],
    group: usize,
    c: usize,
) {
    debug_assert_eq!(g.len(), z1.len());
    debug_assert_eq!(g.len(), z2.len());
    debug_assert_eq!(g.len(), z3.len());
    debug_assert_eq!(g.len(), gt0.len() * group);
    let rows = if c == 0 { 0 } else { g.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = gt0.as_mut_ptr().add(p * c);
        let gp = g.as_ptr().add(r * c);
        let z1p = z1.as_ptr().add(r * c);
        let z2p = z2.as_ptr().add(r * c);
        let z3p = z3.as_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let mut j = 0;
        while j + L::N <= c {
            L::ld(op.add(j))
                .add(o3_t0_expr::<L>(
                    L::ld(gp.add(j)),
                    L::ld(z1p.add(j)),
                    L::ld(z2p.add(j)),
                    L::ld(z3p.add(j)),
                    L::ld(tp.add(j)),
                ))
                .st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) += o3_t0_expr::<f32>(
                *gp.add(j),
                *z1p.add(j),
                *z2p.add(j),
                *z3p.add(j),
                *tp.add(j),
            );
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// gt0[p] += g·(f3' z1³ + 3 f2' z1 z2 + f1' z3), group-summed in
    /// row order.
    jet_o3_bwd_t0 => jet_o3_bwd_t0_body(gt0: &mut [f32], g: &[f32], z1: &[f32], z2: &[f32], z3: &[f32], t0: &[f32], group: usize, c: usize)
}

#[inline(always)]
unsafe fn o4_z1_expr<L: Lanes>(g: L, z1: L, z2: L, z3: L, t: L) -> L {
    let f1 = f1_of(t);
    let f2 = f2_of(t, f1);
    let f3 = f3_of(t, f1);
    let f4 = f4_of(t, f1);
    g.mul(
        L::splat(4.0)
            .mul(f4)
            .mul(z1)
            .mul(z1)
            .mul(z1)
            .add(L::splat(12.0).mul(f3).mul(z1).mul(z2))
            .add(L::splat(4.0).mul(f2).mul(z3)),
    )
}

#[inline(always)]
#[allow(clippy::too_many_arguments)]
unsafe fn jet_o4_bwd_z1_body<L: Lanes>(
    gz1: &mut [f32],
    g: &[f32],
    z1: &[f32],
    z2: &[f32],
    z3: &[f32],
    t0: &[f32],
    group: usize,
    c: usize,
) {
    debug_assert_eq!(gz1.len(), g.len());
    debug_assert_eq!(gz1.len(), z1.len());
    debug_assert_eq!(gz1.len(), z2.len());
    debug_assert_eq!(gz1.len(), z3.len());
    debug_assert_eq!(gz1.len(), t0.len() * group);
    let rows = if c == 0 { 0 } else { gz1.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = gz1.as_mut_ptr().add(r * c);
        let gp = g.as_ptr().add(r * c);
        let z1p = z1.as_ptr().add(r * c);
        let z2p = z2.as_ptr().add(r * c);
        let z3p = z3.as_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let mut j = 0;
        while j + L::N <= c {
            L::ld(op.add(j))
                .add(o4_z1_expr::<L>(
                    L::ld(gp.add(j)),
                    L::ld(z1p.add(j)),
                    L::ld(z2p.add(j)),
                    L::ld(z3p.add(j)),
                    L::ld(tp.add(j)),
                ))
                .st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) += o4_z1_expr::<f32>(
                *gp.add(j),
                *z1p.add(j),
                *z2p.add(j),
                *z3p.add(j),
                *tp.add(j),
            );
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// gz1 += g·(4 f4 z1³ + 12 f3 z1 z2 + 4 f2 z3) (order-4 z1 adjoint).
    jet_o4_bwd_z1 => jet_o4_bwd_z1_body(gz1: &mut [f32], g: &[f32], z1: &[f32], z2: &[f32], z3: &[f32], t0: &[f32], group: usize, c: usize)
}

#[inline(always)]
unsafe fn o4_z2_expr<L: Lanes>(g: L, z1: L, z2: L, t: L) -> L {
    let f1 = f1_of(t);
    let f2 = f2_of(t, f1);
    let f3 = f3_of(t, f1);
    g.mul(
        L::splat(6.0)
            .mul(f3)
            .mul(z1)
            .mul(z1)
            .add(L::splat(6.0).mul(f2).mul(z2)),
    )
}

#[inline(always)]
unsafe fn jet_o4_bwd_z2_body<L: Lanes>(
    gz2: &mut [f32],
    g: &[f32],
    z1: &[f32],
    z2: &[f32],
    t0: &[f32],
    group: usize,
    c: usize,
) {
    debug_assert_eq!(gz2.len(), g.len());
    debug_assert_eq!(gz2.len(), z1.len());
    debug_assert_eq!(gz2.len(), z2.len());
    debug_assert_eq!(gz2.len(), t0.len() * group);
    let rows = if c == 0 { 0 } else { gz2.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = gz2.as_mut_ptr().add(r * c);
        let gp = g.as_ptr().add(r * c);
        let z1p = z1.as_ptr().add(r * c);
        let z2p = z2.as_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let mut j = 0;
        while j + L::N <= c {
            L::ld(op.add(j))
                .add(o4_z2_expr::<L>(
                    L::ld(gp.add(j)),
                    L::ld(z1p.add(j)),
                    L::ld(z2p.add(j)),
                    L::ld(tp.add(j)),
                ))
                .st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) += o4_z2_expr::<f32>(*gp.add(j), *z1p.add(j), *z2p.add(j), *tp.add(j));
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// gz2 += g·(6 f3 z1² + 6 f2 z2) (order-4 z2 adjoint).
    jet_o4_bwd_z2 => jet_o4_bwd_z2_body(gz2: &mut [f32], g: &[f32], z1: &[f32], z2: &[f32], t0: &[f32], group: usize, c: usize)
}

#[inline(always)]
unsafe fn o4_t0_expr<L: Lanes>(g: L, z1: L, z2: L, z3: L, z4: L, t: L) -> L {
    let t2 = t.mul(t);
    let f1p = f1p_of(t);
    let f2p = f2p_of(t2);
    let f3p = f3p_of(t, t2);
    let f4p = f4p_of(t2);
    g.mul(
        f4p.mul(z1)
            .mul(z1)
            .mul(z1)
            .mul(z1)
            .add(L::splat(6.0).mul(f3p).mul(z1).mul(z1).mul(z2))
            .add(L::splat(3.0).mul(f2p).mul(z2).mul(z2))
            .add(L::splat(4.0).mul(f2p).mul(z1).mul(z3))
            .add(f1p.mul(z4)),
    )
}

#[inline(always)]
#[allow(clippy::too_many_arguments)]
unsafe fn jet_o4_bwd_t0_body<L: Lanes>(
    gt0: &mut [f32],
    g: &[f32],
    z1: &[f32],
    z2: &[f32],
    z3: &[f32],
    z4: &[f32],
    t0: &[f32],
    group: usize,
    c: usize,
) {
    debug_assert_eq!(g.len(), z1.len());
    debug_assert_eq!(g.len(), z2.len());
    debug_assert_eq!(g.len(), z3.len());
    debug_assert_eq!(g.len(), z4.len());
    debug_assert_eq!(g.len(), gt0.len() * group);
    let rows = if c == 0 { 0 } else { g.len() / c };
    for r in 0..rows {
        let p = r / group;
        let op = gt0.as_mut_ptr().add(p * c);
        let gp = g.as_ptr().add(r * c);
        let z1p = z1.as_ptr().add(r * c);
        let z2p = z2.as_ptr().add(r * c);
        let z3p = z3.as_ptr().add(r * c);
        let z4p = z4.as_ptr().add(r * c);
        let tp = t0.as_ptr().add(p * c);
        let mut j = 0;
        while j + L::N <= c {
            L::ld(op.add(j))
                .add(o4_t0_expr::<L>(
                    L::ld(gp.add(j)),
                    L::ld(z1p.add(j)),
                    L::ld(z2p.add(j)),
                    L::ld(z3p.add(j)),
                    L::ld(z4p.add(j)),
                    L::ld(tp.add(j)),
                ))
                .st(op.add(j));
            j += L::N;
        }
        while j < c {
            *op.add(j) += o4_t0_expr::<f32>(
                *gp.add(j),
                *z1p.add(j),
                *z2p.add(j),
                *z3p.add(j),
                *z4p.add(j),
                *tp.add(j),
            );
            j += 1;
        }
    }
}

dispatch_kernel! {
    /// gt0[p] += g·(f4' z1⁴ + 6 f3' z1² z2 + 3 f2' z2² + 4 f2' z1 z3 +
    /// f1' z4), group-summed in row order.
    jet_o4_bwd_t0 => jet_o4_bwd_t0_body(gt0: &mut [f32], g: &[f32], z1: &[f32], z2: &[f32], z3: &[f32], z4: &[f32], t0: &[f32], group: usize, c: usize)
}

// ---------------------------------------------------------------------------
// Matmul bodies (generic over lanes; dispatched from tensor::matmul).
// Unlike the elementwise kernels above these are compiled only for the
// simd feature: the default build's matmul path is the hand-written
// scalar reference in `tensor::matmul` (whose slice iterators are the
// autovectorization-friendly shape the §8 gates were tuned on), so
// these bodies would otherwise be dead code under `-D warnings`.
// ---------------------------------------------------------------------------

#[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
const KC: usize = 256;

/// out[m, n] += a[m, k] @ b[k, n] — lane-parallel across output columns,
/// 4 k-terms per pass over the output row; each output element's chain
/// is the scalar one (o + a0·b0 + a1·b1 + a2·b2 + a3·b3 in t order).
#[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
#[inline(always)]
unsafe fn matmul_acc_lanes<L: Lanes>(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        for i in 0..m {
            let arow = a.as_ptr().add(i * k + k0);
            let op = out.as_mut_ptr().add(i * n);
            let mut t = 0;
            while t + 4 <= kb {
                let a0 = *arow.add(t);
                let a1 = *arow.add(t + 1);
                let a2 = *arow.add(t + 2);
                let a3 = *arow.add(t + 3);
                let b0 = b.as_ptr().add((k0 + t) * n);
                let b1 = b.as_ptr().add((k0 + t + 1) * n);
                let b2 = b.as_ptr().add((k0 + t + 2) * n);
                let b3 = b.as_ptr().add((k0 + t + 3) * n);
                let va0 = L::splat(a0);
                let va1 = L::splat(a1);
                let va2 = L::splat(a2);
                let va3 = L::splat(a3);
                let mut j = 0;
                while j + L::N <= n {
                    let mut acc = L::ld(op.add(j));
                    acc = acc.add(va0.mul(L::ld(b0.add(j))));
                    acc = acc.add(va1.mul(L::ld(b1.add(j))));
                    acc = acc.add(va2.mul(L::ld(b2.add(j))));
                    acc = acc.add(va3.mul(L::ld(b3.add(j))));
                    acc.st(op.add(j));
                    j += L::N;
                }
                while j < n {
                    let mut acc = *op.add(j);
                    acc += a0 * *b0.add(j);
                    acc += a1 * *b1.add(j);
                    acc += a2 * *b2.add(j);
                    acc += a3 * *b3.add(j);
                    *op.add(j) = acc;
                    j += 1;
                }
                t += 4;
            }
            while t < kb {
                let av = *arow.add(t);
                let vav = L::splat(av);
                let bp = b.as_ptr().add((k0 + t) * n);
                let mut j = 0;
                while j + L::N <= n {
                    L::ld(op.add(j)).add(vav.mul(L::ld(bp.add(j)))).st(op.add(j));
                    j += L::N;
                }
                while j < n {
                    *op.add(j) += av * *bp.add(j);
                    j += 1;
                }
                t += 1;
            }
        }
        k0 += kb;
    }
}

/// out[m, n] += a^T @ b with a: [rows, m], b: [rows, n] — lane-parallel
/// across the B row, 4 output rows per pass; per-element chains stay in
/// t (row) order.
#[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
#[inline(always)]
unsafe fn matmul_tn_lanes<L: Lanes>(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: usize,
    m: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), rows * m);
    debug_assert_eq!(b.len(), rows * n);
    debug_assert_eq!(out.len(), m * n);
    for t in 0..rows {
        let arow = a.as_ptr().add(t * m);
        let brow = b.as_ptr().add(t * n);
        let mut i = 0;
        while i + 4 <= m {
            let va0 = L::splat(*arow.add(i));
            let va1 = L::splat(*arow.add(i + 1));
            let va2 = L::splat(*arow.add(i + 2));
            let va3 = L::splat(*arow.add(i + 3));
            let r0 = out.as_mut_ptr().add(i * n);
            let r1 = out.as_mut_ptr().add((i + 1) * n);
            let r2 = out.as_mut_ptr().add((i + 2) * n);
            let r3 = out.as_mut_ptr().add((i + 3) * n);
            let mut j = 0;
            while j + L::N <= n {
                let bv = L::ld(brow.add(j));
                L::ld(r0.add(j)).add(va0.mul(bv)).st(r0.add(j));
                L::ld(r1.add(j)).add(va1.mul(bv)).st(r1.add(j));
                L::ld(r2.add(j)).add(va2.mul(bv)).st(r2.add(j));
                L::ld(r3.add(j)).add(va3.mul(bv)).st(r3.add(j));
                j += L::N;
            }
            while j < n {
                let bv = *brow.add(j);
                *r0.add(j) += *arow.add(i) * bv;
                *r1.add(j) += *arow.add(i + 1) * bv;
                *r2.add(j) += *arow.add(i + 2) * bv;
                *r3.add(j) += *arow.add(i + 3) * bv;
                j += 1;
            }
            i += 4;
        }
        while i < m {
            let av = *arow.add(i);
            let vav = L::splat(av);
            let orow = out.as_mut_ptr().add(i * n);
            let mut j = 0;
            while j + L::N <= n {
                L::ld(orow.add(j)).add(vav.mul(L::ld(brow.add(j)))).st(orow.add(j));
                j += L::N;
            }
            while j < n {
                *orow.add(j) += av * *brow.add(j);
                j += 1;
            }
            i += 1;
        }
    }
}

#[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
std::thread_local! {
    /// Per-thread transpose panel for the NT kernel ([k, L::N] at most);
    /// grows once and is reused, so steady-state steps stay
    /// allocation-free (each engine worker owns its own).
    static NT_PANEL: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// out[m, n] += a @ b^T with a: [m, k], b: [n, k] — a block of lane-many
/// b rows is transposed into a contiguous [k, N] panel so each lane owns
/// one output column's dot chain, accumulated in plain t order and added
/// to `out` exactly once (the scalar reference's rounding).
#[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
#[inline(always)]
unsafe fn matmul_nt_lanes<L: Lanes>(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    NT_PANEL.with(|cell| {
        let mut panel = cell.borrow_mut();
        panel.clear();
        panel.resize(k * L::N, 0.0);
        // SAFETY: indices stay inside the debug_asserted slice shapes
        // (the closure body is a fresh safety context inside this
        // unsafe fn).
        unsafe {
            let mut j0 = 0;
            while j0 + L::N <= n {
                for l in 0..L::N {
                    let brow = b.as_ptr().add((j0 + l) * k);
                    for t in 0..k {
                        *panel.as_mut_ptr().add(t * L::N + l) = *brow.add(t);
                    }
                }
                let pp = panel.as_ptr();
                for i in 0..m {
                    let arow = a.as_ptr().add(i * k);
                    let mut acc = L::splat(0.0);
                    for t in 0..k {
                        acc = acc.add(L::splat(*arow.add(t)).mul(L::ld(pp.add(t * L::N))));
                    }
                    let op = out.as_mut_ptr().add(i * n + j0);
                    L::ld(op).add(acc).st(op);
                }
                j0 += L::N;
            }
            for j in j0..n {
                let brow = b.as_ptr().add(j * k);
                for i in 0..m {
                    let arow = a.as_ptr().add(i * k);
                    let mut acc = 0.0f32;
                    for t in 0..k {
                        acc += *arow.add(t) * *brow.add(t);
                    }
                    *out.as_mut_ptr().add(i * n + j) += acc;
                }
            }
        }
    });
}

// The matmul entry points live in `tensor::matmul`; these wrappers give
// them (and the property tests) monomorphized vector bodies to dispatch
// to.

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod matmul_avx2 {
    use super::{matmul_acc_lanes, matmul_nt_lanes, matmul_tn_lanes, V8};

    /// # Safety
    /// Caller must have verified AVX2 support (the `Avx2` dispatch level).
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        matmul_acc_lanes::<V8>(a, b, out, m, k, n)
    }

    /// # Safety
    /// Caller must have verified AVX2 support (the `Avx2` dispatch level).
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul_tn_acc(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        rows: usize,
        m: usize,
        n: usize,
    ) {
        matmul_tn_lanes::<V8>(a, b, out, rows, m, n)
    }

    /// # Safety
    /// Caller must have verified AVX2 support (the `Avx2` dispatch level).
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul_nt_acc(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        matmul_nt_lanes::<V8>(a, b, out, m, k, n)
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub use matmul_avx2::{
    matmul_acc as matmul_acc_avx2, matmul_nt_acc as matmul_nt_acc_avx2,
    matmul_tn_acc as matmul_tn_acc_avx2,
};

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod matmul_neon {
    use super::{matmul_acc_lanes, matmul_nt_lanes, matmul_tn_lanes, V4};

    /// # Safety
    /// NEON is part of the aarch64 baseline; the pointer/length contracts
    /// are the `debug_assert`ed slice shapes.
    pub unsafe fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        matmul_acc_lanes::<V4>(a, b, out, m, k, n)
    }

    /// # Safety
    /// See [`matmul_acc`].
    pub unsafe fn matmul_tn_acc(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        rows: usize,
        m: usize,
        n: usize,
    ) {
        matmul_tn_lanes::<V4>(a, b, out, rows, m, n)
    }

    /// # Safety
    /// See [`matmul_acc`].
    pub unsafe fn matmul_nt_acc(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        matmul_nt_lanes::<V4>(a, b, out, m, k, n)
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
pub use matmul_neon::{
    matmul_acc as matmul_acc_neon, matmul_nt_acc as matmul_nt_acc_neon,
    matmul_tn_acc as matmul_tn_acc_neon,
};

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(seed: &mut u64) -> f32 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((*seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
    }

    fn fill(seed: &mut u64, len: usize) -> Vec<f32> {
        (0..len).map(|_| lcg(seed)).collect()
    }

    fn assert_bits(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len(), "{what} length");
        for (idx, (x, y)) in got.iter().zip(want).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what} elem {idx}: {x} vs {y}");
        }
    }

    #[test]
    fn level_env_override_resolution() {
        let det = detect_simd_level();
        assert_eq!(level_from_env(Some("scalar"), det), SimdLevel::Scalar);
        assert_eq!(level_from_env(None, det), det);
        // an unavailable level falls back to the detected one
        assert_eq!(level_from_env(Some("nonsense"), det), det);
        if det != SimdLevel::Avx2 {
            assert_eq!(level_from_env(Some("avx2"), det), det);
        } else {
            assert_eq!(level_from_env(Some("avx2"), det), SimdLevel::Avx2);
        }
    }

    #[test]
    fn force_level_validates_against_host() {
        let _guard = simd_level_guard();
        let prior = simd_level();
        assert_eq!(force_simd_level(SimdLevel::Scalar), SimdLevel::Scalar);
        let det = detect_simd_level();
        // forcing the detected level sticks; forcing the *other* vector
        // level degrades to scalar
        assert_eq!(force_simd_level(det), det);
        let other = match det {
            SimdLevel::Avx2 => SimdLevel::Neon,
            _ => SimdLevel::Avx2,
        };
        assert_eq!(force_simd_level(other), SimdLevel::Scalar);
        force_simd_level(prior);
    }

    /// Every elementwise kernel, dispatched at the forced vector level,
    /// must be bitwise identical to its forced-scalar run — across
    /// remainder-heavy shapes (c not a multiple of any lane width) and
    /// group broadcasts.
    #[test]
    fn elementwise_kernels_bitwise_match_scalar_dispatch() {
        let _guard = simd_level_guard();
        let prior = simd_level();
        let vector = detect_simd_level();
        let mut seed = 9u64;
        for (n, group, c) in [
            (1, 1, 1),
            (2, 3, 5),
            (3, 2, 7),
            (2, 4, 8),
            (1, 5, 17),
            (3, 3, 33),
            (2, 2, 128),
        ] {
            let b = n * group;
            let t0 = fill(&mut seed, n * c);
            let g = fill(&mut seed, b * c);
            let z1 = fill(&mut seed, b * c);
            let z2 = fill(&mut seed, b * c);
            let z3 = fill(&mut seed, b * c);
            let z4 = fill(&mut seed, b * c);
            let init = fill(&mut seed, b * c);
            let init_n = fill(&mut seed, n * c);
            let bias = fill(&mut seed, c);
            let alpha = lcg(&mut seed);

            // (name, closure writing its result into a fresh buffer)
            type Kernel<'a> = (&'a str, Box<dyn Fn() -> Vec<f32> + 'a>);
            let kernels: Vec<Kernel<'_>> = vec![
                ("acc_add", Box::new(|| {
                    let mut o = init.clone();
                    acc_add(&mut o, &g);
                    o
                })),
                ("acc_sub", Box::new(|| {
                    let mut o = init.clone();
                    acc_sub(&mut o, &g);
                    o
                })),
                ("acc_scaled", Box::new(|| {
                    let mut o = init.clone();
                    acc_scaled(&mut o, &g, alpha);
                    o
                })),
                ("acc_mul", Box::new(|| {
                    let mut o = init.clone();
                    acc_mul(&mut o, &g, &z1);
                    o
                })),
                ("acc_splat", Box::new(|| {
                    let mut o = init.clone();
                    acc_splat(&mut o, alpha);
                    o
                })),
                ("add_rows", Box::new(|| {
                    let mut o = vec![0.0; b * c];
                    add_rows(&mut o, &g, &bias, c);
                    o
                })),
                ("add_rows_inplace", Box::new(|| {
                    let mut o = g.clone();
                    add_rows_inplace(&mut o, &bias, c);
                    o
                })),
                ("broadcast_rows_bwd", Box::new(|| {
                    let mut o = init_n.clone();
                    broadcast_rows_bwd(&mut o, &g, group, c);
                    o
                })),
                ("jet_o1_fwd", Box::new(|| {
                    let mut o = vec![0.0; b * c];
                    jet_o1_fwd(&mut o, &t0, &z1, group, c);
                    o
                })),
                ("jet_o2_fwd", Box::new(|| {
                    let mut o = vec![0.0; b * c];
                    jet_o2_fwd(&mut o, &t0, &z1, &z2, group, c);
                    o
                })),
                ("jet_o3_fwd", Box::new(|| {
                    let mut o = vec![0.0; b * c];
                    jet_o3_fwd(&mut o, &t0, &z1, &z2, &z3, group, c);
                    o
                })),
                ("jet_o4_fwd", Box::new(|| {
                    let mut o = vec![0.0; b * c];
                    jet_o4_fwd(&mut o, &t0, &z1, &z2, &z3, &z4, group, c);
                    o
                })),
                ("jet_f1_acc", Box::new(|| {
                    let mut o = init.clone();
                    jet_f1_acc(&mut o, &g, &t0, group, c);
                    o
                })),
                ("jet_f2z1_acc", Box::new(|| {
                    let mut o = init.clone();
                    jet_f2z1_acc(&mut o, &g, &z1, &t0, 3.0, group, c);
                    o
                })),
                ("jet_o1_bwd_t0", Box::new(|| {
                    let mut o = init_n.clone();
                    jet_o1_bwd_t0(&mut o, &g, &z1, &t0, group, c);
                    o
                })),
                ("jet_o2_bwd_t0", Box::new(|| {
                    let mut o = init_n.clone();
                    jet_o2_bwd_t0(&mut o, &g, &z1, &z2, &t0, group, c);
                    o
                })),
                ("jet_o3_bwd_z1", Box::new(|| {
                    let mut o = init.clone();
                    jet_o3_bwd_z1(&mut o, &g, &z1, &z2, &t0, group, c);
                    o
                })),
                ("jet_o3_bwd_t0", Box::new(|| {
                    let mut o = init_n.clone();
                    jet_o3_bwd_t0(&mut o, &g, &z1, &z2, &z3, &t0, group, c);
                    o
                })),
                ("jet_o4_bwd_z1", Box::new(|| {
                    let mut o = init.clone();
                    jet_o4_bwd_z1(&mut o, &g, &z1, &z2, &z3, &t0, group, c);
                    o
                })),
                ("jet_o4_bwd_z2", Box::new(|| {
                    let mut o = init.clone();
                    jet_o4_bwd_z2(&mut o, &g, &z1, &z2, &t0, group, c);
                    o
                })),
                ("jet_o4_bwd_t0", Box::new(|| {
                    let mut o = init_n.clone();
                    jet_o4_bwd_t0(&mut o, &g, &z1, &z2, &z3, &z4, &t0, group, c);
                    o
                })),
            ];
            for (name, run) in &kernels {
                force_simd_level(SimdLevel::Scalar);
                let scalar = run();
                force_simd_level(vector);
                let vectorized = run();
                assert_bits(
                    &vectorized,
                    &scalar,
                    &format!("{name} (n={n}, group={group}, c={c}, level={})", vector.name()),
                );
            }
        }
        force_simd_level(prior);
    }

    /// The in-place bias add used by the fused plan instructions must be
    /// bitwise the two-buffer [`add_rows`] it replaces, at every forced
    /// SIMD level and across remainder-lane widths — the §12 fusion
    /// contract at the kernel layer.
    #[test]
    fn fused_plan_bias_inplace_bitwise_matches_unfused() {
        let _guard = simd_level_guard();
        let prior = simd_level();
        let mut levels = vec![SimdLevel::Scalar];
        let vector = detect_simd_level();
        if vector != SimdLevel::Scalar {
            levels.push(vector);
        }
        let mut seed = 77u64;
        for (rows, c) in [(1usize, 1usize), (2, 5), (3, 7), (4, 8), (2, 17), (5, 33), (2, 128)] {
            let a = fill(&mut seed, rows * c);
            let bias = fill(&mut seed, c);
            for &level in &levels {
                force_simd_level(level);
                let mut unfused = vec![0.0f32; rows * c];
                add_rows(&mut unfused, &a, &bias, c);
                let mut fused = a.clone();
                add_rows_inplace(&mut fused, &bias, c);
                assert_bits(
                    &fused,
                    &unfused,
                    &format!("add_rows_inplace vs add_rows (rows={rows}, c={c}, {})", level.name()),
                );
            }
        }
        force_simd_level(prior);
    }

    /// The generic matmul bodies, dispatched at the vector level, match
    /// the forced-scalar dispatch bitwise over remainder-heavy shapes.
    #[test]
    fn matmul_lanes_bitwise_match_scalar_dispatch() {
        let _guard = simd_level_guard();
        let prior = simd_level();
        let vector = detect_simd_level();
        let mut seed = 23u64;
        for (m, k, n) in [
            (1, 1, 1),
            (3, 5, 7),
            (4, 8, 8),
            (5, 13, 9),
            (7, 257, 19),
            (12, 64, 33),
            (6, 130, 128),
        ] {
            let a = fill(&mut seed, m * k);
            let b = fill(&mut seed, k * n);
            let a_tn = fill(&mut seed, k * m);
            let b_nt = fill(&mut seed, n * k);
            let init = fill(&mut seed, m * n);

            let run = |which: usize| -> Vec<f32> {
                let mut o = init.clone();
                match which {
                    0 => crate::tensor::matmul_acc(&a, &b, &mut o, m, k, n),
                    1 => crate::tensor::matmul_tn_acc(&a_tn, &b, &mut o, k, m, n),
                    _ => crate::tensor::matmul_nt_acc(&a, &b_nt, &mut o, m, k, n),
                }
                o
            };
            for which in 0..3 {
                force_simd_level(SimdLevel::Scalar);
                let scalar = run(which);
                force_simd_level(vector);
                let vectorized = run(which);
                assert_bits(
                    &vectorized,
                    &scalar,
                    &format!("matmul variant {which} ({m},{k},{n}) level={}", vector.name()),
                );
            }
        }
        force_simd_level(prior);
    }
}
