//! Cache-blocked f32 matmul kernels for the native engine.
//!
//! i-k-j loop order (streaming writes over the output row) with k-blocking
//! so the B panel stays in L1/L2.  All kernels are branch-free over the
//! data: an earlier revision skipped `a == 0.0` terms, which looks like a
//! win for the sparse SDGD probe rows but defeats autovectorization on the
//! dense activations that dominate the hot path (see the `matmul/…` rows
//! of `cargo bench --bench perf_breakdown` for the before/after).
//!
//! The `_acc` variants accumulate (`out +=`) so reverse-mode gradient
//! contributions sum directly into pooled buffers without a temporary.

const KC: usize = 256;

/// out[m, n] += a[m, k] @ b[k, n]
pub fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        for i in 0..m {
            let arow = &a[i * k + k0..i * k + k0 + kb];
            let orow = &mut out[i * n..(i + 1) * n];
            for (t, &av) in arow.iter().enumerate() {
                let brow = &b[(k0 + t) * n..(k0 + t + 1) * n];
                // autovectorizes to fused multiply-adds over the row
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        k0 += kb;
    }
}

/// out[m, n] = a[m, k] @ b[k, n]
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    out.fill(0.0);
    matmul_acc(a, b, out, m, k, n);
}

/// out[m, n] += a^T @ b with a: [rows, m], b: [rows, n] (weight gradients).
pub fn matmul_tn_acc(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), rows * m);
    debug_assert_eq!(b.len(), rows * n);
    debug_assert_eq!(out.len(), m * n);
    for t in 0..rows {
        let arow = &a[t * m..(t + 1) * m];
        let brow = &b[t * n..(t + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// out[m, n] = a^T @ b with a: [rows, m], b: [rows, n].
pub fn matmul_tn_into(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, m: usize, n: usize) {
    out.fill(0.0);
    matmul_tn_acc(a, b, out, rows, m, n);
}

/// out[m, n] += a @ b^T with a: [m, k], b: [n, k] (activation gradients).
pub fn matmul_nt_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (o, brow) in orow.iter_mut().zip(b.chunks_exact(k)) {
            let mut acc = 0.0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *o += acc;
        }
    }
}

/// out[m, n] = a @ b^T with a: [m, k], b: [n, k].
pub fn matmul_nt_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    out.fill(0.0);
    matmul_nt_acc(a, b, out, m, k, n);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for t in 0..k {
                    acc += a[i * k + t] * b[t * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    fn lcg(seed: &mut u64) -> f32 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((*seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
    }

    #[test]
    fn matches_naive_across_shapes_including_blocking_boundary() {
        let mut seed = 1u64;
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (16, 300, 8), (7, 513, 3)] {
            let a: Vec<f32> = (0..m * k).map(|_| lcg(&mut seed)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| lcg(&mut seed)).collect();
            let mut out = vec![0.0f32; m * n];
            matmul_into(&a, &b, &mut out, m, k, n);
            let want = naive(&a, &b, m, k, n);
            for (x, y) in out.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "({m},{k},{n}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn transposed_accumulating_variants_match_naive() {
        let mut seed = 7u64;
        let (rows, m, n) = (9, 4, 6);
        let a: Vec<f32> = (0..rows * m).map(|_| lcg(&mut seed)).collect();
        let b: Vec<f32> = (0..rows * n).map(|_| lcg(&mut seed)).collect();
        // a^T @ b against naive over the explicit transpose
        let mut at = vec![0.0f32; m * rows];
        for t in 0..rows {
            for i in 0..m {
                at[i * rows + t] = a[t * m + i];
            }
        }
        let want_tn = naive(&at, &b, m, rows, n);
        let mut out = vec![1.0f32; m * n]; // nonzero: _acc must add on top
        matmul_tn_acc(&a, &b, &mut out, rows, m, n);
        for (x, y) in out.iter().zip(&want_tn) {
            assert!((x - (y + 1.0)).abs() < 1e-3, "tn: {x} vs {y}+1");
        }
        let mut out2 = vec![0.0f32; m * n];
        matmul_tn_into(&a, &b, &mut out2, rows, m, n);
        for (x, y) in out2.iter().zip(&want_tn) {
            assert!((x - y).abs() < 1e-3, "tn_into: {x} vs {y}");
        }
        // a @ b^T: a [m2, k2], b [n2, k2]
        let (m2, k2, n2) = (5, 8, 3);
        let a2: Vec<f32> = (0..m2 * k2).map(|_| lcg(&mut seed)).collect();
        let b2: Vec<f32> = (0..n2 * k2).map(|_| lcg(&mut seed)).collect();
        let mut b2t = vec![0.0f32; k2 * n2];
        for j in 0..n2 {
            for t in 0..k2 {
                b2t[t * n2 + j] = b2[j * k2 + t];
            }
        }
        let want_nt = naive(&a2, &b2t, m2, k2, n2);
        let mut out3 = vec![0.0f32; m2 * n2];
        matmul_nt_into(&a2, &b2, &mut out3, m2, k2, n2);
        for (x, y) in out3.iter().zip(&want_nt) {
            assert!((x - y).abs() < 1e-3, "nt: {x} vs {y}");
        }
    }
}
