//! Cache-blocked f32 matmul kernels for the native engine.
//!
//! i-k-j loop order (streaming writes over the output row) with k-blocking
//! so the B panel stays in L1/L2, and 4-wide unrolled accumulator
//! microkernels in every inner loop.  The unroll is always across
//! *independent* accumulation chains — four k-terms added sequentially
//! into one output, four output rows sharing one B row, four output
//! columns sharing one A row — never a reassociation of a single chain,
//! so every kernel is **bitwise identical** to the scalar reference
//! (gated by the exactness tests below; the engine's thread-count
//! determinism depends on it).  The win is memory traffic: the 4-wide
//! bodies make one pass over the hot row where the scalar loop made four.
//!
//! All kernels are branch-free over the data: an earlier revision skipped
//! `a == 0.0` terms, which looks like a win for the sparse SDGD probe
//! rows but defeats autovectorization on the dense activations that
//! dominate the hot path (see the `matmul/…` rows of `cargo bench
//! --bench perf_breakdown` for the before/after).
//!
//! The `_acc` variants accumulate (`out +=`) so reverse-mode gradient
//! contributions sum directly into pooled buffers without a temporary.
//!
//! Every public entry point dispatches on the cached
//! [`super::simd::SimdLevel`]: with the `simd` cargo feature and a
//! vector level detected, the body comes from `tensor::simd` (AVX2 /
//! NEON, lanes across independent chains only, no FMA contraction —
//! bitwise identical to the `_scalar` kernels below, which remain the
//! reference implementation and the only code path of the default
//! build).

#[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
use super::simd;
#[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
use super::simd::SimdLevel;

const KC: usize = 256;

/// out[m, n] += a[m, k] @ b[k, n]
pub fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::simd_level() == SimdLevel::Avx2 {
            // SAFETY: the Avx2 level is only installed after runtime
            // detection succeeded.
            unsafe { simd::matmul_acc_avx2(a, b, out, m, k, n) };
            return;
        }
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        if simd::simd_level() == SimdLevel::Neon {
            // SAFETY: NEON is part of the aarch64 baseline.
            unsafe { simd::matmul_acc_neon(a, b, out, m, k, n) };
            return;
        }
    }
    matmul_acc_scalar(a, b, out, m, k, n)
}

/// Scalar reference body of [`matmul_acc`] (4-wide unrolled across
/// independent chains; the bitwise ground truth for every SIMD level).
pub fn matmul_acc_scalar(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        for i in 0..m {
            let arow = &a[i * k + k0..i * k + k0 + kb];
            let orow = &mut out[i * n..(i + 1) * n];
            let mut t = 0;
            // 4 k-terms per pass over the output row: the adds into each
            // output stay sequential (same rounding as the scalar loop),
            // but orow is loaded/stored once instead of four times
            while t + 4 <= kb {
                let (a0, a1, a2, a3) = (arow[t], arow[t + 1], arow[t + 2], arow[t + 3]);
                let b0 = &b[(k0 + t) * n..(k0 + t + 1) * n];
                let b1 = &b[(k0 + t + 1) * n..(k0 + t + 2) * n];
                let b2 = &b[(k0 + t + 2) * n..(k0 + t + 3) * n];
                let b3 = &b[(k0 + t + 3) * n..(k0 + t + 4) * n];
                for (j, o) in orow.iter_mut().enumerate() {
                    let mut acc = *o;
                    acc += a0 * b0[j];
                    acc += a1 * b1[j];
                    acc += a2 * b2[j];
                    acc += a3 * b3[j];
                    *o = acc;
                }
                t += 4;
            }
            while t < kb {
                let av = arow[t];
                let brow = &b[(k0 + t) * n..(k0 + t + 1) * n];
                // autovectorizes to fused multiply-adds over the row
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
                t += 1;
            }
        }
        k0 += kb;
    }
}

/// out[m, n] = a[m, k] @ b[k, n]
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    out.fill(0.0);
    matmul_acc(a, b, out, m, k, n);
}

/// out[m, n] = a[m, k] @ b[k, n] + bias[n] — the fused `Matmul+AddRow`
/// superinstruction (DESIGN.md §12).  Exactly [`matmul_into`] followed by
/// the in-place row-broadcast bias add: the same kernels run in the same
/// order, only the unfused intermediate buffer is gone, so the result is
/// `to_bits`-identical to the two-instruction composition at every SIMD
/// level.
pub fn fused_matmul_bias(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    matmul_into(a, b, out, m, k, n);
    crate::tensor::simd::add_rows_inplace(out, bias, n);
}

/// out[m, n] = tanh(a[m, k] @ b[k, n] + bias[n]) — the fused
/// `Matmul+AddRow+Tanh` superinstruction.  The activation is the same
/// scalar `f32::tanh` the eager tape and the unfused `Tanh` instruction
/// apply, element by element in row-major order, so fusion changes no
/// bits (§12's fusion contract).
pub fn fused_matmul_bias_tanh(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    fused_matmul_bias(a, b, bias, out, m, k, n);
    for x in out.iter_mut() {
        *x = x.tanh();
    }
}

/// out[m, n] += a^T @ b with a: [rows, m], b: [rows, n] (weight gradients).
pub fn matmul_tn_acc(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, m: usize, n: usize) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::simd_level() == SimdLevel::Avx2 {
            // SAFETY: the Avx2 level is only installed after runtime
            // detection succeeded.
            unsafe { simd::matmul_tn_acc_avx2(a, b, out, rows, m, n) };
            return;
        }
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        if simd::simd_level() == SimdLevel::Neon {
            // SAFETY: NEON is part of the aarch64 baseline.
            unsafe { simd::matmul_tn_acc_neon(a, b, out, rows, m, n) };
            return;
        }
    }
    matmul_tn_acc_scalar(a, b, out, rows, m, n)
}

/// Scalar reference body of [`matmul_tn_acc`].
pub fn matmul_tn_acc_scalar(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: usize,
    m: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), rows * m);
    debug_assert_eq!(b.len(), rows * n);
    debug_assert_eq!(out.len(), m * n);
    for t in 0..rows {
        let arow = &a[t * m..(t + 1) * m];
        let brow = &b[t * n..(t + 1) * n];
        let mut i = 0;
        // 4 output rows per pass over the B row; each output's t-order
        // accumulation is untouched
        while i + 4 <= m {
            let (a0, a1, a2, a3) = (arow[i], arow[i + 1], arow[i + 2], arow[i + 3]);
            let block = &mut out[i * n..(i + 4) * n];
            let (r0, rest) = block.split_at_mut(n);
            let (r1, rest) = rest.split_at_mut(n);
            let (r2, r3) = rest.split_at_mut(n);
            for (j, &bv) in brow.iter().enumerate() {
                r0[j] += a0 * bv;
                r1[j] += a1 * bv;
                r2[j] += a2 * bv;
                r3[j] += a3 * bv;
            }
            i += 4;
        }
        while i < m {
            let av = arow[i];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
            i += 1;
        }
    }
}

/// out[m, n] = a^T @ b with a: [rows, m], b: [rows, n].
pub fn matmul_tn_into(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, m: usize, n: usize) {
    out.fill(0.0);
    matmul_tn_acc(a, b, out, rows, m, n);
}

/// out[m, n] += a @ b^T with a: [m, k], b: [n, k] (activation gradients).
pub fn matmul_nt_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::simd_level() == SimdLevel::Avx2 {
            // SAFETY: the Avx2 level is only installed after runtime
            // detection succeeded.
            unsafe { simd::matmul_nt_acc_avx2(a, b, out, m, k, n) };
            return;
        }
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        if simd::simd_level() == SimdLevel::Neon {
            // SAFETY: NEON is part of the aarch64 baseline.
            unsafe { simd::matmul_nt_acc_neon(a, b, out, m, k, n) };
            return;
        }
    }
    matmul_nt_acc_scalar(a, b, out, m, k, n)
}

/// Scalar reference body of [`matmul_nt_acc`] (independent dot-product
/// accumulators; each sums in plain k order, added to `out` once).
pub fn matmul_nt_acc_scalar(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j = 0;
        // 4 independent dot-product accumulators per pass over the A
        // row; each accumulator sums in plain k order
        while j + 4 <= n {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let b2 = &b[(j + 2) * k..(j + 3) * k];
            let b3 = &b[(j + 3) * k..(j + 4) * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for (t, &x) in arow.iter().enumerate() {
                s0 += x * b0[t];
                s1 += x * b1[t];
                s2 += x * b2[t];
                s3 += x * b3[t];
            }
            orow[j] += s0;
            orow[j + 1] += s1;
            orow[j + 2] += s2;
            orow[j + 3] += s3;
            j += 4;
        }
        while j < n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            orow[j] += acc;
            j += 1;
        }
    }
}

/// out[m, n] = a @ b^T with a: [m, k], b: [n, k].
pub fn matmul_nt_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    out.fill(0.0);
    matmul_nt_acc(a, b, out, m, k, n);
}

#[cfg(test)]
mod tests {
    use super::*;

    // -- scalar references: the pre-microkernel loops, one add at a time --

    fn scalar_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        for i in 0..m {
            for t in 0..k {
                let av = a[i * k + t];
                for j in 0..n {
                    out[i * n + j] += av * b[t * n + j];
                }
            }
        }
    }

    fn scalar_tn_acc(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, m: usize, n: usize) {
        for t in 0..rows {
            for i in 0..m {
                let av = a[t * m + i];
                for j in 0..n {
                    out[i * n + j] += av * b[t * n + j];
                }
            }
        }
    }

    fn scalar_nt_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for t in 0..k {
                    acc += a[i * k + t] * b[j * k + t];
                }
                out[i * n + j] += acc;
            }
        }
    }

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for t in 0..k {
                    acc += a[i * k + t] * b[t * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    fn lcg(seed: &mut u64) -> f32 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((*seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
    }

    fn fill(seed: &mut u64, len: usize) -> Vec<f32> {
        (0..len).map(|_| lcg(seed)).collect()
    }

    fn assert_bitwise(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len());
        for (idx, (x, y)) in got.iter().zip(want).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what} elem {idx}: {x} vs {y}");
        }
    }

    /// The unrolled microkernels must be *bitwise* equal to the scalar
    /// reference loops — the unroll may not reassociate any accumulation
    /// chain.  Shapes cover all unroll remainders (dims ≡ 0..3 mod 4)
    /// and the KC blocking boundary.  Run through the public dispatchers
    /// at both the forced-scalar and the detected SIMD level, so the
    /// vector bodies are held to the same reference.
    #[test]
    fn microkernels_bitwise_match_scalar_reference() {
        use crate::tensor::simd::{detect_simd_level, force_simd_level, simd_level_guard, SimdLevel};
        let _guard = simd_level_guard();
        let prior = crate::tensor::simd::simd_level();
        for level in [SimdLevel::Scalar, detect_simd_level()] {
            force_simd_level(level);
            check_dispatch_matches_reference();
        }
        force_simd_level(prior);
    }

    fn check_dispatch_matches_reference() {
        let mut seed = 3u64;
        for (m, k, n) in [
            (1, 1, 1),
            (2, 3, 5),
            (4, 4, 4),
            (5, 6, 7),
            (7, 9, 2),
            (8, 255, 3),
            (3, 256, 8),
            (6, 513, 5),
            (16, 128, 128),
        ] {
            let a = fill(&mut seed, m * k);
            let b = fill(&mut seed, k * n);
            let init = fill(&mut seed, m * n);

            let mut got = init.clone();
            matmul_acc(&a, &b, &mut got, m, k, n);
            let mut want = init.clone();
            scalar_acc(&a, &b, &mut want, m, k, n);
            assert_bitwise(&got, &want, &format!("matmul_acc ({m},{k},{n})"));

            // tn: a is [rows=k, m2=m], b is [rows=k, n]
            let a_tn = fill(&mut seed, k * m);
            let init_tn = fill(&mut seed, m * n);
            let mut got = init_tn.clone();
            matmul_tn_acc(&a_tn, &b, &mut got, k, m, n);
            let mut want = init_tn.clone();
            scalar_tn_acc(&a_tn, &b, &mut want, k, m, n);
            assert_bitwise(&got, &want, &format!("matmul_tn_acc ({k},{m},{n})"));

            // nt: a is [m, k], b is [n, k]
            let b_nt = fill(&mut seed, n * k);
            let mut got = init.clone();
            matmul_nt_acc(&a, &b_nt, &mut got, m, k, n);
            let mut want = init.clone();
            scalar_nt_acc(&a, &b_nt, &mut want, m, k, n);
            assert_bitwise(&got, &want, &format!("matmul_nt_acc ({m},{k},{n})"));

            // _into variants: zero-fill + acc, bitwise too
            let mut got = vec![1.0f32; m * n];
            matmul_into(&a, &b, &mut got, m, k, n);
            let mut want = vec![0.0f32; m * n];
            scalar_acc(&a, &b, &mut want, m, k, n);
            assert_bitwise(&got, &want, &format!("matmul_into ({m},{k},{n})"));
            let mut got = vec![1.0f32; m * n];
            matmul_tn_into(&a_tn, &b, &mut got, k, m, n);
            let mut want = vec![0.0f32; m * n];
            scalar_tn_acc(&a_tn, &b, &mut want, k, m, n);
            assert_bitwise(&got, &want, &format!("matmul_tn_into ({k},{m},{n})"));
            let mut got = vec![1.0f32; m * n];
            matmul_nt_into(&a, &b_nt, &mut got, m, k, n);
            let mut want = vec![0.0f32; m * n];
            scalar_nt_acc(&a, &b_nt, &mut want, m, k, n);
            assert_bitwise(&got, &want, &format!("matmul_nt_into ({m},{k},{n})"));
        }
    }

    /// The fused `Matmul+AddRow(+Tanh)` plan superinstructions must be
    /// bitwise the unfused instruction composition they replace — per
    /// fused pattern, per forced SIMD level, across remainder-lane shapes
    /// (the §12 fusion contract at the kernel layer).
    #[test]
    fn fused_plan_kernels_bitwise_match_unfused_composition() {
        use crate::tensor::simd::{
            add_rows, detect_simd_level, force_simd_level, simd_level_guard, SimdLevel,
        };
        let _guard = simd_level_guard();
        let prior = crate::tensor::simd::simd_level();
        let mut levels = vec![SimdLevel::Scalar];
        let vector = detect_simd_level();
        if vector != SimdLevel::Scalar {
            levels.push(vector);
        }
        let mut seed = 11u64;
        for (m, k, n) in [
            (1, 1, 1),
            (2, 3, 5),
            (4, 4, 4),
            (5, 6, 7),
            (3, 256, 8),
            (6, 513, 5),
            (4, 128, 33),
        ] {
            let a = fill(&mut seed, m * k);
            let b = fill(&mut seed, k * n);
            let bias = fill(&mut seed, n);
            for &level in &levels {
                force_simd_level(level);
                // unfused: Matmul (fill + acc) into z, AddRow z -> h
                let mut z = vec![1.0f32; m * n];
                matmul_into(&a, &b, &mut z, m, k, n);
                let mut h = vec![0.0f32; m * n];
                add_rows(&mut h, &z, &bias, n);
                let mut fused = vec![1.0f32; m * n];
                fused_matmul_bias(&a, &b, &bias, &mut fused, m, k, n);
                assert_bitwise(
                    &fused,
                    &h,
                    &format!("fused_matmul_bias ({m},{k},{n}) level={level:?}"),
                );
                // …then the standalone Tanh instruction on h
                let t: Vec<f32> = h.iter().map(|x| x.tanh()).collect();
                let mut fused_t = vec![1.0f32; m * n];
                fused_matmul_bias_tanh(&a, &b, &bias, &mut fused_t, m, k, n);
                assert_bitwise(
                    &fused_t,
                    &t,
                    &format!("fused_matmul_bias_tanh ({m},{k},{n}) level={level:?}"),
                );
            }
        }
        force_simd_level(prior);
    }

    #[test]
    fn matches_naive_across_shapes_including_blocking_boundary() {
        let mut seed = 1u64;
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (16, 300, 8), (7, 513, 3)] {
            let a: Vec<f32> = (0..m * k).map(|_| lcg(&mut seed)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| lcg(&mut seed)).collect();
            let mut out = vec![0.0f32; m * n];
            matmul_into(&a, &b, &mut out, m, k, n);
            let want = naive(&a, &b, m, k, n);
            for (x, y) in out.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "({m},{k},{n}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn transposed_accumulating_variants_match_naive() {
        let mut seed = 7u64;
        let (rows, m, n) = (9, 4, 6);
        let a: Vec<f32> = (0..rows * m).map(|_| lcg(&mut seed)).collect();
        let b: Vec<f32> = (0..rows * n).map(|_| lcg(&mut seed)).collect();
        // a^T @ b against naive over the explicit transpose
        let mut at = vec![0.0f32; m * rows];
        for t in 0..rows {
            for i in 0..m {
                at[i * rows + t] = a[t * m + i];
            }
        }
        let want_tn = naive(&at, &b, m, rows, n);
        let mut out = vec![1.0f32; m * n]; // nonzero: _acc must add on top
        matmul_tn_acc(&a, &b, &mut out, rows, m, n);
        for (x, y) in out.iter().zip(&want_tn) {
            assert!((x - (y + 1.0)).abs() < 1e-3, "tn: {x} vs {y}+1");
        }
        let mut out2 = vec![0.0f32; m * n];
        matmul_tn_into(&a, &b, &mut out2, rows, m, n);
        for (x, y) in out2.iter().zip(&want_tn) {
            assert!((x - y).abs() < 1e-3, "tn_into: {x} vs {y}");
        }
        // a @ b^T: a [m2, k2], b [n2, k2]
        let (m2, k2, n2) = (5, 8, 3);
        let a2: Vec<f32> = (0..m2 * k2).map(|_| lcg(&mut seed)).collect();
        let b2: Vec<f32> = (0..n2 * k2).map(|_| lcg(&mut seed)).collect();
        let mut b2t = vec![0.0f32; k2 * n2];
        for j in 0..n2 {
            for t in 0..k2 {
                b2t[t * n2 + j] = b2[j * k2 + t];
            }
        }
        let want_nt = naive(&a2, &b2t, m2, k2, n2);
        let mut out3 = vec![0.0f32; m2 * n2];
        matmul_nt_into(&a2, &b2, &mut out3, m2, k2, n2);
        for (x, y) in out3.iter().zip(&want_nt) {
            assert!((x - y).abs() < 1e-3, "nt: {x} vs {y}");
        }
    }
}
