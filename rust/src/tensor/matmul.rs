//! Cache-blocked f32 matmul kernel for the native engine.
//!
//! i-k-j loop order (streaming writes over the output row) with k-blocking
//! so the B panel stays in L1/L2.  Good enough for the native
//! validation/ablation engine; the production hot path runs through XLA.

const KC: usize = 256;

/// out[m, n] += 0; out = a[m, k] @ b[k, n]
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        for i in 0..m {
            let arow = &a[i * k + k0..i * k + k0 + kb];
            let orow = &mut out[i * n..(i + 1) * n];
            for (t, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[(k0 + t) * n..(k0 + t + 1) * n];
                // autovectorizes to fused multiply-adds over the row
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        k0 += kb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for t in 0..k {
                    acc += a[i * k + t] * b[t * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn matches_naive_across_shapes_including_blocking_boundary() {
        let mut seed = 1u64;
        let mut next = move || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        };
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (16, 300, 8), (7, 513, 3)] {
            let a: Vec<f32> = (0..m * k).map(|_| next()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| next()).collect();
            let mut out = vec![0.0f32; m * n];
            matmul_into(&a, &b, &mut out, m, k, n);
            let want = naive(&a, &b, m, k, n);
            for (x, y) in out.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "({m},{k},{n}): {x} vs {y}");
            }
        }
    }
}
