//! Analytic memory model for the paper's MB columns.
//!
//! The paper measures GPU memory with `nvidia-smi` on an A100; on this CPU
//! testbed we report RSS for the dims we actually run, and use this model
//! to reproduce the *shape* of the paper's memory columns — the O(d^2·N)
//! full-Hessian blow-up vs the O(V·(K+1)·N·H) flat HTE cost, and the
//! ">80GB" OOM crossovers (Tables 1, 4, 5).
//!
//! Calibration against the paper's own numbers (see EXPERIMENTS.md):
//!   * BASE ≈ 800 MB framework-resident memory (the floor of every column;
//!     HTE at 100k-D measures 1089MB vs 869MB at 100-D — ratio 1.25, which
//!     only a large additive base explains).
//!   * order-2 full PINN ≈ N·d²·4B·1.4 (Hessian + reverse-over-reverse
//!     copies): 13.5 GB at d=5000 (paper 14,283MB), OOM between 10k and
//!     20k dims (paper: N.A. at 10k).
//!   * order-4 full PINN ≈ N·d²·4B·40·H (the backward graph through the
//!     Hessian-of-Laplacian): 5.3 GB at 50-D (paper 6,199MB), 45 GB at
//!     150-D (paper 44,631MB), OOM just past 200-D (paper: N.A. at 200-D).

/// Bytes per f32.
const F32: f64 = 4.0;
/// The paper's network: 4 layers, width 128.
const HIDDEN: f64 = 128.0;
const DEPTH: f64 = 4.0;
/// Framework-resident floor (CUDA context / XLA runtime), calibrated.
const BASE: f64 = 800.0 * 1024.0 * 1024.0;

#[derive(Clone, Copy, Debug)]
pub struct MemEstimate {
    pub bytes: f64,
}

impl MemEstimate {
    pub fn mb(&self) -> f64 {
        self.bytes / (1024.0 * 1024.0)
    }
    pub fn gb(&self) -> f64 {
        self.bytes / (1024.0 * 1024.0 * 1024.0)
    }
    /// The paper's A100 limit.
    pub fn ooms_80gb(&self) -> bool {
        self.gb() > 80.0
    }
}

/// Parameter + Adam state bytes for the width-128 depth-4 MLP at dim d.
pub fn state_bytes(d: usize) -> f64 {
    let d = d as f64;
    let params = d * HIDDEN + HIDDEN // layer 1
        + 2.0 * (HIDDEN * HIDDEN + HIDDEN) // layers 2-3
        + HIDDEN + 1.0; // head
    3.0 * params * F32 // params + m + v
}

/// Vanilla PINN baseline: materialized derivative tensors + AD copies.
/// `order` = 2 (Hessian trace) or 4 (biharmonic, nested Hessians).
pub fn full_pinn_bytes(d: usize, batch: usize, order: usize) -> MemEstimate {
    let d_f = d as f64;
    let n = batch as f64;
    let per_point = if order >= 4 {
        // backward graph through Hessian-of-Laplacian: ~40·H live copies
        // of the d x d second-order pass (calibrated on Table 5)
        d_f * d_f * F32 * 40.0 * HIDDEN
    } else {
        // Hessian + reverse-over-reverse evaluation-trace copies
        d_f * d_f * F32 * 1.4
    };
    let tape = n * HIDDEN * DEPTH * F32 * 2f64.powi(order as i32);
    MemEstimate { bytes: BASE + state_bytes(d) + n * per_point + tape + n * d_f * HIDDEN * F32 }
}

/// HTE / SDGD: V probes x (K+1) Taylor streams through the width-H net;
/// no derivative tensor is ever materialized (the contraction is scalar).
pub fn hte_bytes(d: usize, batch: usize, v: usize, order: usize) -> MemEstimate {
    let n = batch as f64;
    let streams = (order + 1) as f64;
    let act = n * v as f64 * streams * HIDDEN * DEPTH * F32;
    let probes = v as f64 * d as f64 * F32;
    MemEstimate { bytes: BASE + state_bytes(d) + act + probes + n * d as f64 * F32 }
}

/// Full-Hessian gPINN baseline (Table 4's exact-gPINN column): the
/// order-2 full-PINN footprint plus the gradient-of-residual term, whose
/// reverse pass re-materializes the d×d Hessian evaluation trace once
/// more — the reason the paper's exact gPINN column goes "N.A." at even
/// smaller d than the vanilla PINN budget allows.
pub fn gpinn_full_bytes(d: usize, batch: usize) -> MemEstimate {
    let base = full_pinn_bytes(d, batch, 2);
    let extra = batch as f64 * d as f64 * d as f64 * F32 * 1.4;
    MemEstimate { bytes: base.bytes + extra }
}

/// Native gPINN tape estimate: the order-3 instantiation of
/// [`native_tape_bytes`] (four jet streams — primal + D¹..D³ — through
/// the shared pipeline; the gradient-of-residual contraction adds
/// leaves, not streams).
pub fn gpinn_native_tape_bytes(d: usize, chunk: usize, v: usize, threads: usize) -> MemEstimate {
    native_tape_bytes(d, chunk, v, 3, threads)
}

/// Native-engine (CPU tape) live-footprint model — what the order-4 rows
/// of `BENCH_native.json` cross-check against measured `rss_mb`.
///
/// The A100/XLA narrative above does not transfer to the native engine:
/// there is no ~800MB framework floor, and the batch is sharded into
/// fixed `chunk`-point tasks (`nn::CHUNK_POINTS`), so the live tape per
/// worker scales with the chunk, not the batch — roughly two nodes per
/// layer per stream (linear + activation), values + gradients, plus
/// parameter leaves/gradients per worker and the packed Adam state.  The
/// paper's biharmonic OOM crossover (order-4 *full* PINN past ~200-D,
/// Table 5) comes from the `d²·H` nested-Hessian term in
/// [`full_pinn_bytes`]; the TVP engine never materializes it, which this
/// model makes concrete: its order-4 cost is ~(1+4V)/(1+2V) ≈ 2× the
/// order-2 cost at the same V, flat in d beyond the parameter vectors.
pub fn native_tape_bytes(
    d: usize,
    chunk: usize,
    v: usize,
    order: usize,
    threads: usize,
) -> MemEstimate {
    let params = state_bytes(d) / (3.0 * F32); // parameter count
    let rows = chunk as f64 * (1.0 + order as f64 * v as f64);
    let per_worker = rows * HIDDEN * DEPTH * 2.0 * 2.0 * F32 + 2.0 * params * F32;
    // workers' tapes + packed Adam state (params|m|v) + the Mlp itself
    MemEstimate { bytes: threads as f64 * per_worker + state_bytes(d) + params * F32 }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 1's crossover: full PINN runs at 5k dims (paper: 14GB),
    /// OOMs by the 10-20k decade, while HTE stays almost flat through
    /// 100k dims (paper: 869MB -> 1089MB, ratio 1.25).
    #[test]
    fn table1_oom_crossover_shape() {
        let at_5k = full_pinn_bytes(5_000, 100, 2);
        assert!(!at_5k.ooms_80gb());
        assert!(at_5k.gb() > 5.0 && at_5k.gb() < 30.0, "{}", at_5k.gb());
        assert!(full_pinn_bytes(20_000, 100, 2).ooms_80gb());
        assert!(full_pinn_bytes(100_000, 100, 2).ooms_80gb());
        let hte_100 = hte_bytes(100, 100, 16, 2);
        let hte_100k = hte_bytes(100_000, 100, 16, 2);
        let ratio = hte_100k.bytes / hte_100.bytes;
        assert!(ratio < 2.0, "HTE growth ratio {ratio}");
        assert!(!hte_100k.ooms_80gb());
    }

    /// Table 5's shape: the biharmonic baseline OOMs at far smaller d
    /// than the second-order case — paper: ~200-D vs ~10k-D.
    #[test]
    fn biharmonic_ooms_earlier() {
        let d_oom_2nd = (1..).map(|k| k * 1000).find(|&d| full_pinn_bytes(d, 100, 2).ooms_80gb());
        let d_oom_4th = (1..).map(|k| k * 10).find(|&d| full_pinn_bytes(d, 100, 4).ooms_80gb());
        let (d2, d4) = (d_oom_2nd.unwrap(), d_oom_4th.unwrap());
        assert!(d4 < d2 / 10, "4th-order OOM {d4} vs 2nd-order {d2}");
        // the paper's actual crossover: N.A. at 200-D for the biharmonic
        assert!((150..=300).contains(&d4), "biharmonic OOM at {d4}");
    }

    /// Calibration spot-checks against the paper's measured MB columns.
    #[test]
    fn matches_paper_magnitudes() {
        // Table 1: PINN 5000-D = 14,283MB
        let p5k = full_pinn_bytes(5_000, 100, 2).mb();
        assert!((p5k - 14_283.0).abs() / 14_283.0 < 0.25, "{p5k}");
        // Table 5: PINN 150-D = 44,631MB
        let b150 = full_pinn_bytes(150, 100, 4).mb();
        assert!((b150 - 44_631.0).abs() / 44_631.0 < 0.25, "{b150}");
        // Table 1: HTE 100k-D = 1089MB
        let h100k = hte_bytes(100_000, 100, 16, 2).mb();
        assert!((h100k - 1_089.0).abs() / 1_089.0 < 0.35, "{h100k}");
    }

    /// HTE memory grows with V but stays far below the full baseline
    /// (Table 5: V=1024 still ~12x cheaper than PINN at 150D).
    #[test]
    fn hte_v_growth_is_mild() {
        let v16 = hte_bytes(150, 100, 16, 4);
        let v1024 = hte_bytes(150, 100, 1024, 4);
        let full = full_pinn_bytes(150, 100, 4);
        assert!(v1024.bytes > v16.bytes);
        assert!(v1024.bytes < full.bytes / 5.0);
    }

    /// The exact-gPINN baseline always costs more than the vanilla PINN
    /// at the same shape (it adds a Hessian-trace re-materialization),
    /// while the native gPINN tape sits between the order-2 and order-4
    /// stream counts and stays flat in d.
    #[test]
    fn gpinn_model_orderings() {
        for d in [100usize, 1000, 5000] {
            assert!(gpinn_full_bytes(d, 100).bytes > full_pinn_bytes(d, 100, 2).bytes);
        }
        assert!(gpinn_full_bytes(20_000, 100).ooms_80gb());
        let o2 = native_tape_bytes(100, 4, 16, 2, 8);
        let o3 = gpinn_native_tape_bytes(100, 4, 16, 8);
        let o4 = native_tape_bytes(100, 4, 16, 4, 8);
        assert!(o2.bytes < o3.bytes && o3.bytes < o4.bytes);
        assert!(gpinn_native_tape_bytes(10_000, 4, 16, 8).gb() < 1.0);
    }

    #[test]
    fn state_scales_linearly_in_d() {
        let a = state_bytes(1000);
        let b = state_bytes(2000);
        assert!((b - a - 1000.0 * 128.0 * 3.0 * 4.0).abs() < 1.0);
    }

    /// The native order-4 tape is ~2x the order-2 tape at the same V
    /// ((1+4V)/(1+2V) streams) and nowhere near the full-PINN d²-term:
    /// at the paper's biharmonic OOM dimension the native TVP engine
    /// stays in tens of MB while the modeled baseline is past 80GB.
    #[test]
    fn native_tape_order4_stays_flat_where_full_pinn_ooms() {
        let o2 = native_tape_bytes(200, 4, 16, 2, 8);
        let o4 = native_tape_bytes(200, 4, 16, 4, 8);
        let ratio = o4.bytes / o2.bytes;
        assert!(ratio > 1.3 && ratio < 2.5, "order-4/order-2 tape ratio {ratio}");
        let full = full_pinn_bytes(200, 100, 4);
        assert!(full.ooms_80gb(), "baseline should OOM at 200-D");
        assert!(o4.mb() < 100.0, "native order-4 tape {} MB", o4.mb());
        // growing d only adds parameter-vector bytes, not tape bytes
        let wide = native_tape_bytes(10_000, 4, 16, 4, 8);
        assert!(wide.gb() < 1.0, "native tape at 10k-D {} GB", wide.gb());
    }
}
